"""Property-based tests (hypothesis) on the system's invariants."""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis", reason="optional dev dep (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402
from hypothesis.stateful import (  # noqa: E402
    RuleBasedStateMachine, invariant, precondition, rule,
)

from repro.approx import quant
from repro.core import carbon as cb
from repro.core import lut as lutmod
from repro.core import multipliers as mm
from repro.core import netlist as nl

SET = settings(max_examples=25, deadline=None)


@SET
@given(st.integers(0, 2 ** 32 - 1),
       st.integers(1, 8), st.integers(2, 64))
def test_quantize_roundtrip_bound(seed, m, k):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, k)) * rng.uniform(0.1, 100),
                    jnp.float32)
    q, s = quant.quantize(x)
    err = np.abs(np.asarray(quant.dequantize(q, s)) - np.asarray(x))
    assert err.max() <= float(np.asarray(s).max()) * 0.5 + 1e-6
    assert np.asarray(q).min() >= -128 and np.asarray(q).max() <= 127


@SET
@given(st.integers(0, 4), st.integers(0, 4))
def test_truncation_closed_form_property(ta, tb):
    m = mm.truncated(ta, tb)
    a = np.arange(-128, 128, dtype=np.int64)
    ta_v = a - np.mod(a, 2 ** ta) if ta else a
    tb_v = a - np.mod(a, 2 ** tb) if tb else a
    ua = (a & 0xFF).astype(int)
    got = m.lut[np.ix_(ua, ua)].astype(np.int64)
    np.testing.assert_array_equal(got, ta_v[:, None] * tb_v[None, :])


@SET
@given(st.integers(0, 2 ** 32 - 1), st.floats(0.005, 0.10))
def test_pruning_invariants(seed, density):
    rng = np.random.default_rng(seed)
    mask = rng.random(len(nl.bw8().prunable_gates())) < density
    m = mm.pruned(mask, name=f"prop{seed % 1000}")
    ex = mm.exact_multiplier()
    assert m.area_nand2eq <= ex.area_nand2eq + 1e-9
    assert m.stats.nmed <= m.stats.wce / lutmod.MAX_ABS_PRODUCT + 1e-12
    assert 0.0 <= m.stats.error_rate <= 1.0


@SET
@given(st.integers(0, 2 ** 32 - 1))
def test_lowrank_residual_monotone_in_rank(seed):
    """SVD truncation is monotone in the FROBENIUS norm (the L1-based NMED
    may wiggle slightly, so the invariant is asserted on MSE)."""
    rng = np.random.default_rng(seed)
    mask = rng.random(len(nl.bw8().prunable_gates())) < 0.03
    m = mm.pruned(mask, name=f"lrprop{seed % 1000}")
    e = lutmod.error_surface(m.lut).astype(np.float64)
    mses = []
    for r in (0, 1, 2, 4, 8):
        lr = lutmod.lowrank_error(m.lut, r)
        resid = e - (lr.reconstruct() if lr.rank else 0.0)
        mses.append(float((resid ** 2).mean()))
    for a, b in zip(mses, mses[1:]):
        assert b <= a * (1 + 1e-9) + 1e-9


@SET
@given(st.floats(0.5, 500.0), st.floats(0.5, 500.0),
       st.sampled_from([7, 14, 28]))
def test_carbon_monotone_property(a1, a2, node):
    lo, hi = sorted((a1, a2))
    c_lo = cb.embodied_carbon(lo, node).total_g
    c_hi = cb.embodied_carbon(hi, node).total_g
    if hi > lo * 1.001:
        assert c_hi > c_lo
    y = cb.murphy_yield(hi, node)
    assert 0.0 < y <= 1.0


@SET
@given(st.integers(1, 6), st.integers(1, 6), st.integers(1, 6),
       st.integers(0, 2 ** 31 - 1))
def test_approx_gemm_linearity_in_k(m_, n_, k_, seed):
    """sum_k structure: concatenating along K adds contributions exactly."""
    from repro.kernels import ref
    rng = np.random.default_rng(seed)
    mult = mm.truncated(2, 2)
    lut = jnp.asarray(mult.lut)
    a1 = jnp.asarray(rng.integers(-128, 128, (m_, k_)), jnp.int8)
    a2 = jnp.asarray(rng.integers(-128, 128, (m_, k_)), jnp.int8)
    b1 = jnp.asarray(rng.integers(-128, 128, (k_, n_)), jnp.int8)
    b2 = jnp.asarray(rng.integers(-128, 128, (k_, n_)), jnp.int8)
    whole = ref.lut_matmul(jnp.concatenate([a1, a2], 1),
                           jnp.concatenate([b1, b2], 0), lut)
    parts = ref.lut_matmul(a1, b1, lut) + ref.lut_matmul(a2, b2, lut)
    np.testing.assert_array_equal(np.asarray(whole), np.asarray(parts))


@SET
@given(st.integers(1, 4), st.integers(8, 64), st.integers(8, 64),
       st.integers(0, 2 ** 31 - 1))
def test_blockwise_attention_matches_naive(b, sq, d16, seed):
    from repro.models import attention as A
    from repro.models import common as C
    d = (d16 // 8) * 8 or 8
    rng = np.random.default_rng(seed)
    h, kvh = 4, 2
    q = jnp.asarray(rng.standard_normal((b, sq, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, sq, kvh, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, sq, kvh, d)), jnp.float32)
    want = np.asarray(C.naive_attention(q, k, v, causal=True))
    got = np.asarray(A.blockwise_attention(q, k, v, 16, True, 0))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@SET
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 64))
def test_hlo_type_bytes(seed, n):
    from repro.roofline import hlo_parse as hp
    rng = np.random.default_rng(seed)
    dims = rng.integers(1, 64, size=rng.integers(1, 4))
    s = f"bf16[{','.join(map(str, dims))}]"
    assert hp._type_bytes(s) == int(np.prod(dims)) * 2


# --- paged-KV allocator state machine --------------------------------------

class PageAllocatorMachine(RuleBasedStateMachine):
    """Random alloc/free/fork/COW sequences against `PageAllocator`.

    `audit()` runs after every step and re-derives the full invariant
    set from scratch: no double-free survives, writable pages are never
    aliased across requests, refcounts always sum to exactly the
    allocated pages, free/live partition the pool."""

    def __init__(self):
        super().__init__()
        from repro.serving import PageAllocator
        self.alloc = PageAllocator(n_pages=9, page_size=4)
        self.live: set[str] = set()
        self.counter = 0

    @rule(n=st.integers(1, 30), share=st.booleans(),
          prefix_word=st.integers(1, 3))
    def allocate(self, n, share, prefix_word):
        rid = f"r{self.counter}"
        self.counter += 1
        # a tiny prompt alphabet makes prefix collisions (hits) likely
        prompt = tuple([prefix_word] * n) if share else None
        lease = self.alloc.alloc(rid, n, prompt=prompt, digest="d")
        if lease is None:
            return  # pool exhausted: a counted failure, not an error
        assert len(lease.pages) == self.alloc.pages_needed(n)
        assert len(set(lease.pages)) == len(lease.pages)
        self.live.add(rid)
        if prompt is not None:
            self.alloc.register_prefix(rid, prompt, "d")

    @precondition(lambda self: self.live)
    @rule(data=st.data())
    def free(self, data):
        rid = data.draw(st.sampled_from(sorted(self.live)))
        self.alloc.free(rid)
        self.live.discard(rid)

    @precondition(lambda self: self.live)
    @rule(data=st.data())
    def double_free_raises(self, data):
        from repro.serving import PagingError
        rid = data.draw(st.sampled_from(sorted(self.live)))
        self.alloc.free(rid)
        self.live.discard(rid)
        with pytest.raises(PagingError):
            self.alloc.free(rid)

    @precondition(lambda self: self.live)
    @rule(data=st.data())
    def fork(self, data):
        src = data.draw(st.sampled_from(sorted(self.live)))
        dst = f"f{self.counter}"
        self.counter += 1
        table = self.alloc.fork(src, dst)
        assert table == self.alloc.table(src)
        self.live.add(dst)
        # every shared entry is now read-only for BOTH holders
        for i in range(len(table)):
            assert not self.alloc.writable(src, i)
            assert not self.alloc.writable(dst, i)

    @precondition(lambda self: self.live)
    @rule(data=st.data(), idx=st.integers(0, 29))
    def cow(self, data, idx):
        from repro.serving import PagingError
        rid = data.draw(st.sampled_from(sorted(self.live)))
        table = self.alloc.table(rid)
        i = idx % len(table)
        try:
            op = self.alloc.cow(rid, i)
        except PagingError:
            return  # pool exhausted mid-COW: allowed, state unchanged
        if op is None:
            # was already exclusively owned — and stays that way
            assert self.alloc.writable(rid, i)
        else:
            src, dst = op
            assert dst != src and dst == self.alloc.table(rid)[i]
            assert self.alloc.writable(rid, i)

    @invariant()
    def audit(self):
        self.alloc.audit()

    @invariant()
    def trash_page_never_leased(self):
        for rid in self.live:
            assert 0 not in self.alloc.table(rid)


TestPageAllocator = PageAllocatorMachine.TestCase
# Deeper than the module default: the reclaim-under-pressure regime
# (prefix-cached pages + drained free list) needs long alloc/free
# sequences to reach.  The exact eviction race hypothesis missed is
# additionally pinned by deterministic regressions in
# tests/test_serving_paged.py (test_alloc_reclaim_never_evicts_*).
TestPageAllocator.settings = settings(
    max_examples=60, stateful_step_count=60, deadline=None)
