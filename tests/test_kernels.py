"""Pallas kernels (interpret=True on CPU) vs pure-jnp ref.py oracles.

Per the brief: sweep shapes/dtypes per kernel and assert_allclose against the
oracle.  Integer paths (exact / trunc) must be bit-exact; the low-rank path
matches the XLA reference within f32 ULPs (FMA contraction differences only).
"""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.approx import gemm as G
from repro.core import multipliers as mm
from repro.core import netlist as nl
from repro.kernels import approx_qgemm as qk
from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _rand_q(shape):
    return RNG.integers(-128, 128, shape).astype(np.int8)


def _lowrank_spec(rank=6, seed=1):
    rng = np.random.default_rng(seed)
    mask = rng.random(len(nl.bw8().prunable_gates())) < 0.03
    m = mm.pruned(mask, name=f"lr_test_{seed}")
    return m, G.from_multiplier(m, rank=rank)


GEMM_SHAPES = [(8, 16, 8), (64, 96, 80), (128, 128, 128), (100, 130, 50),
               (1, 256, 257), (300, 64, 512)]


@pytest.mark.parametrize("shape", GEMM_SHAPES)
@pytest.mark.parametrize("mult", ["exact", "trunc2x2", "trunc3x1"])
def test_qgemm_kernel_bitexact_int_paths(shape, mult):
    m, k, n = shape
    a, b = _rand_q((m, k)), _rand_q((k, n))
    mobj = mm.get_multiplier(mult)
    spec = G.from_multiplier(mobj)
    oracle = np.asarray(ref.lut_matmul(jnp.asarray(a), jnp.asarray(b),
                                       jnp.asarray(mobj.lut)))
    got = np.asarray(ops.approx_qgemm(jnp.asarray(a), jnp.asarray(b), spec))
    np.testing.assert_array_equal(got, oracle.astype(np.float32))


@pytest.mark.parametrize("shape", [(32, 48, 40), (128, 128, 128),
                                   (65, 130, 33)])
def test_qgemm_kernel_lowrank_matches_xla_reference(shape):
    m, k, n = shape
    a, b = _rand_q((m, k)), _rand_q((k, n))
    _, spec = _lowrank_spec()
    want = np.asarray(ref.ref_approx_qgemm(jnp.asarray(a), jnp.asarray(b),
                                           spec))
    got = np.asarray(ops.approx_qgemm(jnp.asarray(a), jnp.asarray(b), spec))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1.0)


def test_qgemm_lowrank_tracks_lut_oracle_within_residual():
    """The low-rank path approximates the LUT semantic within the residual
    NMED recorded on the spec (mean-level bound, exercised at K=128)."""
    mobj, spec = _lowrank_spec(rank=8, seed=3)
    k = 128
    a, b = _rand_q((64, k)), _rand_q((k, 64))
    oracle = np.asarray(ref.lut_matmul(jnp.asarray(a), jnp.asarray(b),
                                       jnp.asarray(mobj.lut))).astype(np.float64)
    got = np.asarray(ops.approx_qgemm(jnp.asarray(a), jnp.asarray(b),
                                      spec)).astype(np.float64)
    mean_err = np.abs(got - oracle).mean() / k
    # mean per-product error must be of the order of the recorded residual
    assert mean_err <= 16384 * (spec.residual_nmed * 8 + 1e-6), (
        mean_err, spec.residual_nmed)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bh,s,d", [(2, 128, 64), (4, 256, 128), (1, 64, 256)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(bh, s, d, causal, dtype):
    q = jnp.asarray(RNG.standard_normal((bh, s, d)), dtype)
    k = jnp.asarray(RNG.standard_normal((bh, s, d)), dtype)
    v = jnp.asarray(RNG.standard_normal((bh, s, d)), dtype)
    want = np.asarray(ref.ref_attention(q, k, v, causal=causal),
                      dtype=np.float32)
    got = np.asarray(ops.flash_attention(q, k, v, causal=causal,
                                         bq=64, bkv=64), dtype=np.float32)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * 3)


def test_flash_attention_cross_blocks():
    """Block sizes must not change the result."""
    q = jnp.asarray(RNG.standard_normal((2, 256, 64)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((2, 256, 64)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((2, 256, 64)), jnp.float32)
    o1 = np.asarray(ops.flash_attention(q, k, v, bq=64, bkv=128))
    o2 = np.asarray(ops.flash_attention(q, k, v, bq=256, bkv=32))
    np.testing.assert_allclose(o1, o2, rtol=2e-6, atol=1e-6)


@pytest.mark.parametrize("m,k", [(8, 16), (100, 300), (256, 1024), (3, 7)])
def test_quantize_rows_kernel(m, k):
    x = jnp.asarray(RNG.standard_normal((m, k)), jnp.float32)
    q1, s1 = ops.quantize_rows(x)
    q2, s2 = ref.ref_quantize_rows(x)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-7)


FUSED_PARITY_SHAPES = [(64, 96, 80), (128, 128, 128), (100, 130, 50),
                       (1, 256, 257), (33, 257, 65)]


@pytest.mark.parametrize("rank", [1, 2, 4, 8])
@pytest.mark.parametrize("shape", FUSED_PARITY_SHAPES)
def test_fused_matches_stacked_bitexact_lowrank(rank, shape):
    """The in-kernel table map must reproduce the pre-mapped stacked path
    bit-for-bit at every rank and at non-block-multiple shapes (K-tail
    masking of the mapped planes)."""
    m, k, n = shape
    a, b = _rand_q((m, k)), _rand_q((k, n))
    _, spec = _lowrank_spec(rank=rank, seed=rank)
    fused = np.asarray(ops.approx_qgemm(jnp.asarray(a), jnp.asarray(b),
                                        spec))
    stacked = np.asarray(ops.approx_qgemm(jnp.asarray(a), jnp.asarray(b),
                                          spec, fused=False))
    np.testing.assert_array_equal(fused, stacked)


@pytest.mark.parametrize("mult", ["exact", "trunc2x2", "trunc3x1"])
@pytest.mark.parametrize("shape", [(64, 96, 80), (100, 130, 50),
                                   (1, 256, 257)])
def test_fused_matches_stacked_and_xla_bitexact_int_paths(mult, shape):
    """Exact/trunc: fused == stacked == XLA reference, bit-for-bit (the
    trunc mask moves into the kernel)."""
    m, k, n = shape
    a, b = _rand_q((m, k)), _rand_q((k, n))
    spec = G.from_multiplier(mm.get_multiplier(mult))
    fused = np.asarray(ops.approx_qgemm(jnp.asarray(a), jnp.asarray(b),
                                        spec))
    stacked = np.asarray(ops.approx_qgemm(jnp.asarray(a), jnp.asarray(b),
                                          spec, fused=False))
    xla = np.asarray(ref.ref_approx_qgemm(jnp.asarray(a), jnp.asarray(b),
                                          spec))
    np.testing.assert_array_equal(fused, stacked)
    np.testing.assert_array_equal(fused, xla)


@pytest.mark.parametrize("rank", [1, 2, 4, 8])
def test_fused_lowrank_tracks_lut_oracle_within_residual(rank):
    """Fused path approximates the LUT semantic within the residual NMED
    recorded on the spec, at every rank (same bound as the stacked test)."""
    mobj, spec = _lowrank_spec(rank=rank, seed=3)
    k = 130  # non-block-multiple: exercises the in-kernel K-tail mask
    a, b = _rand_q((64, k)), _rand_q((k, 64))
    oracle = np.asarray(ref.lut_matmul(jnp.asarray(a), jnp.asarray(b),
                                       jnp.asarray(mobj.lut))
                        ).astype(np.float64)
    got = np.asarray(ops.approx_qgemm(jnp.asarray(a), jnp.asarray(b),
                                      spec)).astype(np.float64)
    mean_err = np.abs(got - oracle).mean() / k
    assert mean_err <= 16384 * (spec.residual_nmed * 8 + 1e-6), (
        mean_err, spec.residual_nmed)


def test_fused_kernel_masks_fully_padded_k_block():
    """k_valid < K with k_valid % bk == 0 (an entire padded K block) must
    still be masked in the mapped planes — pad zeros map to tbl[0] != 0."""
    _, spec = _lowrank_spec(rank=2, seed=9)
    m = n = k_valid = 128
    a, b = _rand_q((m, k_valid)), _rand_q((k_valid, n))
    ap = np.zeros((m, 256), np.int8)
    ap[:, :k_valid] = a
    bp = np.zeros((256, n), np.int8)
    bp[:k_valid] = b
    scales = jnp.concatenate([jnp.ones((1,), jnp.float32),
                              -spec.s_r])[:, None]
    got = qk.approx_qgemm_fused(
        jnp.asarray(ap), jnp.asarray(bp), spec.fu_q, spec.fv_q, scales,
        k_valid=k_valid, bm=128, bk=128, bn=128, interpret=True)
    want = ops.approx_qgemm(jnp.asarray(a), jnp.asarray(b), spec,
                            fused=False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("trunc", [0, 2, 4])
def test_quantize_rows_trunc_prologue(trunc):
    """Fused quantize+mask == mask-after-quantize bit-for-bit (same kernel
    both sides, so the comparison is exact and order-independent); scales
    are untouched by the mask and track the reference quantizer."""
    x = jnp.asarray(RNG.standard_normal((24, 96)), jnp.float32)
    q1, s1 = ops.quantize_rows(x, trunc=trunc)
    q0, s0 = ops.quantize_rows(x)
    np.testing.assert_array_equal(np.asarray(q1),
                                  np.asarray(G._trunc_mask(q0, trunc)))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s0))
    _, s_ref = ref.ref_quantize_rows(x)
    # kernel vs XLA max-reduction order: within 1 f32 ULP (~1.2e-7 rel)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s_ref), rtol=2e-7)


def test_padding_is_inert():
    """Padded K region must contribute exactly zero even when m(0,0) != 0."""
    mobj, spec = _lowrank_spec(rank=8, seed=5)
    # verify the premise: this multiplier has m(0,0) != 0 or at least some
    # nonzero row/col at zero operands — if not, the test is vacuous but
    # still correct.
    a, b = _rand_q((4, 130)), _rand_q((130, 4))  # K=130 pads to 256
    want = np.asarray(ref.ref_approx_qgemm(jnp.asarray(a), jnp.asarray(b),
                                           spec))
    got = np.asarray(ops.approx_qgemm(jnp.asarray(a), jnp.asarray(b), spec))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1.0)


# ---------------------------------------------------------------------------
# skinny-M decode kernel + plane-unroll schedule knob
# ---------------------------------------------------------------------------

SKINNY_SHAPES = [(1, 256, 256), (4, 200, 256), (8, 384, 130), (32, 512, 256)]


@pytest.mark.parametrize("shape", SKINNY_SHAPES)
@pytest.mark.parametrize("mult", ["exact", "trunc2x2"])
def test_skinny_kernel_bitexact_int_paths(shape, mult):
    """Decode-shaped GEMMs through the skinny-M kernel are bit-identical
    to the LUT oracle on the pure-int paths (incl. odd-K tails)."""
    m, k, n = shape
    a, b = _rand_q((m, k)), _rand_q((k, n))
    mobj = mm.get_multiplier(mult)
    spec = G.from_multiplier(mobj)
    oracle = np.asarray(ref.lut_matmul(jnp.asarray(a), jnp.asarray(b),
                                       jnp.asarray(mobj.lut)))
    got = np.asarray(ops.approx_qgemm(jnp.asarray(a), jnp.asarray(b), spec,
                                      skinny=True))
    np.testing.assert_array_equal(got, oracle.astype(np.float32))


@pytest.mark.parametrize("shape", SKINNY_SHAPES)
@pytest.mark.parametrize("rank", [1, 2, 8])
def test_skinny_matches_fused_bitexact_lowrank(shape, rank):
    """skinny == fused == stacked bit-for-bit at every rank: the same
    integer planes and the same f32 flush combination, so the decode
    layout is purely a schedule change."""
    m, k, n = shape
    a, b = _rand_q((m, k)), _rand_q((k, n))
    _, spec = _lowrank_spec(rank=rank, seed=rank)
    fused = np.asarray(ops.approx_qgemm(jnp.asarray(a), jnp.asarray(b),
                                        spec))
    skinny = np.asarray(ops.approx_qgemm(jnp.asarray(a), jnp.asarray(b),
                                         spec, skinny=True))
    stacked = np.asarray(ops.approx_qgemm(jnp.asarray(a), jnp.asarray(b),
                                          spec, fused=False))
    np.testing.assert_array_equal(skinny, fused)
    np.testing.assert_array_equal(skinny, stacked)


@pytest.mark.parametrize("unroll", [2, 3, 8])
def test_plane_unroll_is_bit_identical(unroll):
    """Plane-unroll groups correction planes into one batched int8 dot —
    integer accumulation, so every unroll factor gives the same bits on
    both the regular fused and the skinny kernels."""
    m, k, n = 16, 200, 128  # odd K: the grouped path must keep the tail mask
    a, b = _rand_q((m, k)), _rand_q((k, n))
    _, spec = _lowrank_spec(rank=8, seed=9)
    base = np.asarray(ops.approx_qgemm(jnp.asarray(a), jnp.asarray(b), spec))
    got = np.asarray(ops.approx_qgemm(jnp.asarray(a), jnp.asarray(b), spec,
                                      unroll=unroll))
    np.testing.assert_array_equal(got, base)
    sk_base = np.asarray(ops.approx_qgemm(jnp.asarray(a), jnp.asarray(b),
                                          spec, skinny=True))
    sk = np.asarray(ops.approx_qgemm(jnp.asarray(a), jnp.asarray(b), spec,
                                     skinny=True, unroll=unroll))
    np.testing.assert_array_equal(sk, sk_base)
    np.testing.assert_array_equal(sk_base, base)


def test_skinny_vmem_scales_with_true_m():
    """The skinny working set must scale with the true row count — the
    whole point of the decode kernel is never paying the 128-row pad."""
    small = qk.skinny_vmem_bytes(1, 512, 256, 3)
    big = qk.fused_vmem_bytes(128, 512, 256, 3)
    assert small < big
    assert qk.skinny_vmem_bytes(32, 512, 256, 3) > small
