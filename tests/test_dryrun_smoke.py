"""Dry-run path smoke coverage: lower+compile one fast cell per step kind
on the production 256-chip mesh, in a subprocess (the 512 placeholder
devices must never leak into the main test process)."""

import json
import os
import subprocess
import sys
import tempfile

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_dryrun(args, timeout=1200):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)  # dryrun.py sets its own, first thing
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun"] + args,
        env=env, capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stdout + out.stderr
    return out.stdout


def test_dryrun_decode_cell_single_pod():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "r.json")
        _run_dryrun(["--arch", "tinyllama-1.1b", "--shape", "decode_32k",
                     "--mesh", "single", "--out", path])
        r = json.load(open(path))[0]
        assert r["ok"]
        rf = r["roofline"]
        assert rf["flops"] > 0 and rf["hbm_bytes"] > 0
        assert rf["bottleneck"] == "memory"  # decode cells stream memory
        assert r["memory"]["tpu_estimate"]["total"] > 0


def test_dryrun_skip_cell_reported():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "r.json")
        _run_dryrun(["--arch", "tinyllama-1.1b", "--shape", "long_500k",
                     "--mesh", "single", "--out", path])
        r = json.load(open(path))[0]
        assert not r["ok"] and "skipped per brief" in r["skip_reason"]


def test_dryrun_multipod_train_cell():
    """The pod axis must shard: the 512-chip compile succeeds and the batch
    is split across pod x data."""
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "r.json")
        _run_dryrun(["--arch", "mamba2-370m", "--shape", "train_4k",
                     "--mesh", "multi", "--out", path])
        r = json.load(open(path))[0]
        assert r["ok"]
        assert r["roofline"]["chips"] == 512
