"""Chaos-engineering tests for the fleet: seeded fault schedules,
spiked grids, and full campaigns whose invariant checkers (zero lost,
exactly-once, meter conservation, deadline accounting, monotone
degrade/restore) must hold — deterministically, from the chaos seed."""

import dataclasses
import functools

import jax
import numpy as np
import pytest

from repro import configs
from repro.fleet import (ChaosCampaign, ChaosSchedule, DegradationConfig,
                         Fleet, FleetConfig, Replica, StaticGrid)
from repro.fleet.chaos import (CHECKERS, ChaosEvent, SpikedGrid,
                               check_exactly_once, check_zero_lost)
from repro.launch.fleet import poisson_requests
from repro.models import api
from repro.serving import Request, SamplingParams

ARCH = "tinyllama-1.1b"


def _cfg():
    return configs.reduced(configs.get_config(ARCH))


@functools.lru_cache(maxsize=1)
def _params():
    return api.init_params(_cfg(), jax.random.key(0))


def _prompt(n, seed, vocab=512):
    return np.random.default_rng(seed).integers(1, vocab, (n,)).tolist()


def _tiered_fleet(slo=32.0):
    cfg, params = _cfg(), _params()
    reps = [Replica(name, cfg, grid=StaticGrid(name), params=params,
                    capacity=2, max_len=48, seed=0,
                    tiers=("exact", "trunc4x4"))
            for name in ("us-west", "eu-west")]
    return Fleet(reps, FleetConfig(
        ttft_slo_ticks=slo, retry_budget=3, probation_steps=2,
        degradation=DegradationConfig(patience=1, min_dwell_ticks=2)))


def _trace(n=8, gen=4, slo=32.0):
    cfg = _cfg()
    return [dataclasses.replace(r, ttft_deadline_ticks=4.0 * slo,
                                deadline_ticks=8.0 * slo)
            for r in poisson_requests(n, 6, gen, cfg.vocab, seed=1)]


# --- schedule / event plumbing ----------------------------------------------

def test_chaos_event_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        ChaosEvent(1, "meteor", "a")
    with pytest.raises(ValueError, match="needs a replica"):
        ChaosEvent(1, "straggler")
    ev = ChaosEvent(3, "transient", "a", recovery_ticks=2)
    assert ev.to_dict() == {"tick": 3, "kind": "transient", "replica": "a",
                            "recovery_ticks": 2}
    burst = ChaosEvent(5, "burst", n_requests=4)
    assert burst.to_dict() == {"tick": 5, "kind": "burst", "n_requests": 4}


def test_chaos_schedule_replayable_from_seed():
    names = ["a", "b", "c"]
    s1 = ChaosSchedule.random(11, names, horizon_ticks=20, n_events=8)
    s2 = ChaosSchedule.random(11, names, horizon_ticks=20, n_events=8)
    assert s1.events == s2.events and s1.seed == 11
    assert len(s1.events) == 8
    assert all(e.kind != "kill" for e in s1.events)  # default pool is safe
    assert [e.tick for e in s1.events] == sorted(e.tick
                                                 for e in s1.events)
    s3 = ChaosSchedule.random(12, names, horizon_ticks=20, n_events=8)
    assert s3.events != s1.events


def test_spiked_grid_windows_routing_view_only():
    base = StaticGrid("us-west")
    g0 = base.g_per_kwh(0.0)
    spiked = SpikedGrid(base=base, t0_s=10.0, t1_s=20.0, factor=4.0)
    assert spiked.region == "us-west"
    assert spiked.g_per_kwh(5.0) == g0
    assert spiked.g_per_kwh(10.0) == pytest.approx(4.0 * g0)
    assert spiked.g_per_kwh(19.99) == pytest.approx(4.0 * g0)
    assert spiked.g_per_kwh(20.0) == g0


# --- campaigns ---------------------------------------------------------------

def test_seeded_campaign_invariants_hold():
    """The random seed-7 campaign (transient crashes w/ recovery,
    submit-boundary deaths, stragglers, grid spikes, bursts) over a
    Poisson trace: every invariant checker must come back clean."""
    fleet = _tiered_fleet()
    schedule = ChaosSchedule.random(7, [r.name for r in fleet.replicas])
    report = ChaosCampaign(fleet, _trace(), schedule).run()
    assert report.ok, report.violations
    assert report.violations == []
    assert report.lost == 0
    assert report.completed == report.submitted
    assert len(report.faults_by_kind) >= 3
    # at least one replica actually died and came back
    assert report.recoveries >= 1
    assert sum(report.restarts.values()) >= 1
    # ...and the retry discipline really re-attempted work
    assert report.requeued >= 1 and report.max_attempt >= 1
    # every replica ends the campaign back on its exact tier
    assert all(t == "exact" for t in report.final_tiers.values())


def test_campaign_is_deterministic():
    """Same (trace, schedule seed) -> bit-identical campaign report,
    including which faults fired, retries, and tier occupancy."""
    def run():
        fleet = _tiered_fleet()
        schedule = ChaosSchedule.random(7, [r.name for r in fleet.replicas])
        return ChaosCampaign(fleet, _trace(), schedule).run().to_dict()

    assert run() == run()


def test_campaign_hand_written_transient_crash():
    """A hand-written schedule: kill the preferred replica mid-trace
    with a 3-tick recovery; its work fails over, it restarts through
    probation, and the meters conserve energy across the restart."""
    fleet = _tiered_fleet()
    trace = _trace(n=6, gen=4)
    schedule = ChaosSchedule(events=(
        ChaosEvent(2, "transient", "us-west", recovery_ticks=3),), seed=0)
    report = ChaosCampaign(fleet, trace, schedule,
                           cooldown_ticks=16).run()
    assert report.ok, report.violations
    assert report.faults_by_kind == {"transient": 1}
    assert report.restarts == {"us-west": 1} and report.recoveries == 1
    assert fleet.replicas[0].alive
    # checkers are also callable standalone
    assert check_zero_lost(fleet, {}) == []
    assert check_exactly_once(
        fleet, {r.request_id: r for r in trace}) == []
    assert len(CHECKERS) == 5


def test_campaign_burst_triggers_brownout():
    """A burst flood on a tight SLO pushes the controller down the
    ladder (approx tokens served, audited), and cooldown restores
    exact — the monotone-tiers checker enforces both directions."""
    fleet = _tiered_fleet(slo=16.0)
    schedule = ChaosSchedule(events=(
        ChaosEvent(1, "burst", n_requests=10),), seed=5)
    report = ChaosCampaign(fleet, [], schedule, cooldown_ticks=24).run()
    assert report.ok, report.violations
    assert report.submitted == 10
    assert report.degradation_events >= 2          # down AND back up
    assert report.tier_occupancy.get("trunc4x4", 0) > 0
    assert all(t == "exact" for t in report.final_tiers.values())
    # wall-clock TTFT under brownout stayed within the (tight) SLO
    assert report.ttft_p95_ticks <= report.ttft_slo_ticks


def test_grid_spike_steers_routing():
    """Spiking the clean region's intensity makes the router prefer the
    other replica for traffic arriving inside the spike window."""
    fleet = _tiered_fleet()
    # without chaos, us-west (263 g/kWh) beats eu-west (346)
    schedule = ChaosSchedule(events=(
        ChaosEvent(0, "grid_spike", "us-west", factor=4.0,
                   duration_ticks=64),), seed=3)
    trace = [Request(f"g{i}", _prompt(5, i),
                     SamplingParams(max_new_tokens=3), arrival=float(i))
             for i in range(4)]
    report = ChaosCampaign(fleet, trace, schedule,
                           cooldown_ticks=4).run()
    assert report.ok, report.violations
    routed = {rec.request_id: rec.replica for rec in fleet.routes}
    assert all(routed[f"g{i}"] == "eu-west" for i in range(4))
