"""Multi-die hardware target: packaging carbon, per-die yield, the
dataflow model's die partition (per-die DRAM channel + D2D all-gather),
the GA's die gene, scenario reporting, and the HardwareTarget bridge
between the co-design and serving layers."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import accelerator as acc
from repro.core import carbon as cb
from repro.core import codesign
from repro.core import dataflow as df
from repro.core import ga
from repro.core import ga_batched as gb
from repro.core import multipliers as mm
from repro.core import target as tg


def _fast_mults():
    return [mm.exact_multiplier(), mm.truncated(1, 1), mm.truncated(2, 2),
            mm.truncated(3, 3)]


# --- carbon ------------------------------------------------------------------

def test_monolithic_collapse():
    """n_dies=1 is exactly the monolithic model: no packaging, same total."""
    mdc = cb.multi_die_carbon(35.0, 1, 7)
    mono = cb.embodied_carbon(35.0, 7)
    assert mdc.packaging_g == 0.0
    assert mdc.total_g == pytest.approx(mono.total_g, rel=1e-12)
    assert cb.packaging_carbon(35.0, 1) == 0.0


def test_yield_favors_small_dies_at_large_area():
    """The paper's chiplet lever: at defect-limited area, 4 small dies
    (plus packaging) beat one 4x die; at tiny area packaging dominates
    and the monolithic die wins."""
    big = cb.embodied_carbon(200.0, 7)
    split = cb.multi_die_carbon(50.0, 4, 7)
    assert split.die_yield > big.yield_
    assert split.total_g < big.total_g
    small = cb.embodied_carbon(2.0, 7)
    small_split = cb.multi_die_carbon(0.5, 4, 7)
    assert small_split.total_g > small.total_g


def test_multi_die_carbon_arr_matches_scalar():
    areas = np.geomspace(0.5, 120.0, 12)
    for n in (1, 2, 4):
        ref = [cb.multi_die_carbon(a, n, 7).total_g for a in areas]
        got = np.asarray(cb.multi_die_carbon_g_arr(
            jnp.asarray(areas, jnp.float32), jnp.float32(n), 7))
        np.testing.assert_allclose(got, ref, rtol=1e-5)


# --- dataflow ----------------------------------------------------------------

def test_dataflow_n_dies_1_unchanged():
    cfg = acc.nvdla_default(512, 7)
    assert df.workload_perf("vgg16", cfg, 1).fps == \
        df.workload_perf("vgg16", cfg).fps


def test_multi_die_lifts_memory_bound_fps():
    """Per-die DRAM channels: a bandwidth-bound workload speeds up with
    dies, sublinearly (replicated ifmap + D2D all-gather)."""
    cfg = acc.AcceleratorConfig(32, 64, 32, 512, "exact", 7)
    f1 = df.workload_perf("vgg16", cfg, 1).fps
    f2 = df.workload_perf("vgg16", cfg, 2).fps
    f4 = df.workload_perf("vgg16", cfg, 4).fps
    assert f1 < f2 < f4
    assert f4 < 4.0 * f1      # D2D + replicated-ifmap tax

    p4 = df.workload_perf("vgg16", cfg, 4)
    assert any(l.d2d_cycles > 0 for l in p4.layers)
    assert all(l.hop_cycles == df.D2D_HOP_CYCLES for l in p4.layers)


def test_batched_fps_die_axis_matches_scalar():
    rows, cols, glbs, dies, ref = [], [], [], [], []
    for pes in (256, 2048):
        for aspect in ga.ASPECTS:
            r, c = ga._pe_split(pes, aspect)
            for d in ga.DIE_CHOICES:
                cfg = acc.AcceleratorConfig(r, c, 32, 128, "exact", 7)
                rows.append(r), cols.append(c), glbs.append(128)
                dies.append(d)
                ref.append(df.workload_perf("resnet50", cfg, d).fps)
    got = np.asarray(df.batched_fps(
        "resnet50", np.array(rows), np.array(cols), np.array(glbs), 7,
        dies=np.array(dies)))
    np.testing.assert_allclose(got, np.array(ref), rtol=1e-4)


# --- GA die gene -------------------------------------------------------------

def test_die_feasibility():
    assert ga.die_feasible(32, 512, 1)
    assert ga.die_feasible(32, 512, 4)       # 128 PEs/die, cols split 8
    assert not ga.die_feasible(2, 64, 4)     # cols 2 cannot split 4 ways
    assert not ga.die_feasible(8, 64, 2)     # 32 PEs/die < smallest array


def test_genome_to_target_roundtrip():
    mults = _fast_mults()
    g = ga.Genome(3, 0, 0, 2, 0, 2)          # 512 PEs, 4 dies
    t = g.to_target(mults, 7)
    assert t.n_dies == 4
    assert t.die.num_pes == 128
    assert t.total_pes == 512
    assert t.tp_degree == 4
    assert dict(t.mesh_axes)["model"] == 4
    assert t.carbon().packaging_g > 0
    # uneven split raises
    with pytest.raises(ValueError):
        ga.Genome(0, 2, 0, 0, 0, 2).to_target(mults, 7)  # tall 64: cols 4


def test_target_mesh_spec_parsing():
    axes = tg.parse_mesh_spec("model=4,data=2")
    assert dict(axes) == {"model": 4, "data": 2}
    assert tg.parse_mesh_spec("") == ()
    with pytest.raises(ValueError):
        tg.parse_mesh_spec("modle=4")
    with pytest.raises(ValueError):
        tg.parse_mesh_spec("model=4,model=2")
    with pytest.raises(ValueError):
        tg.parse_mesh_spec("model=0")
    # mesh model axis must equal die count
    with pytest.raises(ValueError):
        tg.HardwareTarget(die=acc.nvdla_default(64, 7), n_dies=2,
                          mesh_axes=(("model", 4),))
    # a typo'd axis name cannot silently drop to a monolithic mesh
    with pytest.raises(ValueError, match="unknown mesh axis"):
        tg.HardwareTarget(die=acc.nvdla_default(64, 7), n_dies=2,
                          mesh_axes=(("modell", 2),))
    # nor can a missing model axis stand in for n_dies > 1
    with pytest.raises(ValueError, match="model axis"):
        tg.HardwareTarget(die=acc.nvdla_default(64, 7), n_dies=2,
                          mesh_axes=(("data", 2),))
    from repro.launch import mesh as meshmod
    with pytest.raises(ValueError, match="unknown mesh axis"):
        meshmod.mesh_from_axes((("modell", 1),))


def test_calibrate_serving_rejects_target_plus_mesh_spec():
    from repro.core import calibrate as cal
    t = tg.HardwareTarget.monolithic(acc.nvdla_default(64, 7))
    with pytest.raises(ValueError, match="not both"):
        cal.calibrate_serving(target=t, mesh_spec="model=1")


def test_ga_picks_multi_die_when_floor_unreachable_monolithically():
    """vgg16 @ 7nm with a 120-FPS floor: one DRAM channel saturates below
    the floor, so the GA must fire the die gene — and the winner must
    beat the best monolithic design on constrained CDP (the acceptance
    scenario recorded by bench_codesign)."""
    mults = _fast_mults()
    res = gb.run_ga_batched(
        "vgg16", 7, 120.0, 2.0, mults=mults,
        cfg=gb.BatchedGAConfig(pop_size=1024, generations=8, seed=0))
    assert res.best.n_dies > 1
    assert res.best.fps >= 120.0
    assert res.best.packaging_g > 0
    assert 0 < res.best.die_yield <= 1.0
    mono_genome, mono_met = gb.exhaustive_best(res.space, max_dies=1)
    assert mono_genome.n_dies == 1
    assert res.best.fitness < float(mono_met["fitness"])


def test_numpy_ga_supports_die_gene():
    mults = _fast_mults()
    rn = ga.run_ga("vgg16", 7, 120.0, 2.0, mults=mults,
                   cfg=ga.GAConfig(pop_size=32, generations=16, seed=0))
    assert rn.best.n_dies > 1
    assert np.isfinite(rn.best.fitness)


def test_scenario_records_multi_die_fields():
    scen = codesign.multi_die_scenarios()[:1]
    res = codesign.run_scenarios(
        scen, mults=_fast_mults(),
        cfg=gb.BatchedGAConfig(pop_size=512, generations=5, seed=0))
    d = res[0].to_dict()
    best, mono = d["best"], d["best_monolithic"]
    for rec in (best, mono):
        assert {"n_dies", "die_area_mm2", "die_yield", "packaging_g",
                "cdp_constrained"} <= set(rec)
    assert best["n_dies"] > 1
    assert mono["n_dies"] == 1
    assert best["cdp_constrained"] < mono["cdp_constrained"]
    assert best["die_area_mm2"] * best["n_dies"] == \
        pytest.approx(best["area_mm2"], rel=1e-6)


def test_exhaustive_best_max_dies_restriction():
    space = gb.build_space("vgg16", 7, 120.0, 2.0, mults=_fast_mults())
    g_all, met_all = gb.exhaustive_best(space)
    g_mono, met_mono = gb.exhaustive_best(space, max_dies=1)
    assert g_mono.n_dies == 1
    assert float(met_all["fitness"]) <= float(met_mono["fitness"])


# --- calibration bridge ------------------------------------------------------

def test_calibrate_serving_analytical_mirror_scales_with_dies():
    """The analytical side of the TP serving anchor runs the multi-die
    dataflow model (per-die K split): more dies -> faster predicted
    decode on the bandwidth-bound anchor."""
    layers = []
    from repro.core import workloads as wl
    for i in range(2):
        layers += wl.decode_block_gemms(f"l{i}", 256, 1024, 8, 4, 32)
    anchor = acc.nvdla_default(2048, 7)
    f1 = df.layers_perf(layers, anchor, 1).fps
    f4 = df.layers_perf(layers, anchor, 4).fps
    assert f4 > f1
