"""Training infrastructure: optimizers, checkpointing, fault tolerance,
synthetic data, end-to-end loss decrease."""

import os
import subprocess
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import synthetic
from repro.train import checkpoint as ckpt
from repro.train import fault
from repro.train import optimizer as opt

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# --- optimizer -----------------------------------------------------------------

def _quad_problem():
    rng = np.random.default_rng(0)
    target = jnp.asarray(rng.standard_normal((32, 64)), jnp.float32)
    params = {"w": jnp.zeros((32, 64), jnp.float32)}

    def grads(p):
        return {"w": p["w"] - target}

    return params, grads, target


@pytest.mark.parametrize("kind,kw", [
    ("adamw", {"moment_dtype": "f32"}),
    ("adamw", {"moment_dtype": "bf16"}),
    ("adamw", {"moment_dtype": "int8"}),
    ("adafactor", {}),
])
def test_optimizer_converges_on_quadratic(kind, kw):
    params, grads, target = _quad_problem()
    init, update = opt.make_optimizer(
        kind, lr=0.05, total_steps=300, warmup_steps=10, weight_decay=0.0,
        **kw)
    st = init(params)
    for _ in range(300):
        params, st = update(params, grads(params), st)
    err = float(jnp.abs(params["w"] - target).mean())
    assert err < 0.15, err


def test_quantized_moments_close_to_f32():
    params, grads, _ = _quad_problem()
    outs = {}
    for md in ("f32", "int8"):
        p = dict(params)
        init, update = opt.make_optimizer("adamw", lr=0.05, total_steps=100,
                                          warmup_steps=5, weight_decay=0.0,
                                          moment_dtype=md)
        st = init(p)
        for _ in range(50):
            p, st = update(p, grads(p), st)
        outs[md] = np.asarray(p["w"])
    rel = np.abs(outs["int8"] - outs["f32"]).mean() / \
        (np.abs(outs["f32"]).mean() + 1e-9)
    assert rel < 0.05, rel


def test_grad_clip_applies():
    params = {"w": jnp.zeros((4,), jnp.float32)}
    init, update = opt.make_optimizer("adamw", lr=1e-3, total_steps=10,
                                      warmup_steps=0)
    st = init(params)
    big = {"w": jnp.full((4,), 1e6, jnp.float32)}
    p2, _ = update(params, big, st)
    assert np.isfinite(np.asarray(p2["w"])).all()
    assert np.abs(np.asarray(p2["w"])).max() < 1.0


def test_lr_schedule():
    lrs = [float(opt.warmup_cosine(jnp.asarray(s), 1.0, 10, 100))
           for s in [0, 5, 10, 55, 100]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0, abs=0.01)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(0.1, abs=0.02)


# --- checkpointing --------------------------------------------------------------

def _small_state():
    return {"params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                       "b": jnp.ones((4,), jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}


def test_checkpoint_roundtrip_and_prune():
    with tempfile.TemporaryDirectory() as d:
        mgr = ckpt.CheckpointManager(d, keep_last=2)
        state = _small_state()
        for s in (1, 2, 3, 4):
            mgr.save(state, s)
        assert mgr.all_steps() == [3, 4]
        restored, at = mgr.restore(jax.eval_shape(lambda: state))
        assert at == 4
        np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                      np.asarray(state["params"]["w"]))
        assert restored["params"]["b"].dtype == jnp.bfloat16


def test_checkpoint_async_and_atomic():
    with tempfile.TemporaryDirectory() as d:
        mgr = ckpt.CheckpointManager(d)
        mgr.save(_small_state(), 1, blocking=False)
        mgr.wait()
        assert mgr.latest_step() == 1
        # a stale .tmp dir must be ignored
        os.makedirs(os.path.join(d, "step_00000099.tmp"))
        assert mgr.latest_step() == 1


def test_checkpoint_corruption_falls_back():
    with tempfile.TemporaryDirectory() as d:
        mgr = ckpt.CheckpointManager(d)
        state = _small_state()
        mgr.save(state, 1)
        mgr.save(state, 2)
        # corrupt the newest payload
        p = os.path.join(d, "step_00000002", "proc_0.msgpack.zst")
        with open(p, "wb") as f:
            f.write(b"garbage")
        restored, at = mgr.restore(jax.eval_shape(lambda: state))
        assert at == 1


# --- fault tolerance --------------------------------------------------------------

def test_straggler_watchdog():
    wd = fault.StragglerWatchdog(factor=3.0, min_samples=3)
    for s in range(6):
        assert not wd.observe(s, 0.10)
    assert wd.observe(6, 0.50)
    assert wd.flagged == [6]
    assert not wd.observe(7, 0.12)


def test_straggler_watchdog_injectable_clock():
    """step_start/step_end on an injected clock: detection is a pure
    function of the fed timestamps (the fleet's virtual-tick clock uses
    exactly this hook), no wall time involved."""
    t = {"now": 0.0}
    wd = fault.StragglerWatchdog(factor=3.0, min_samples=3,
                                 clock=lambda: t["now"])
    for s in range(5):
        wd.step_start()
        t["now"] += 1.0
        assert not wd.step_end(s)
    wd.step_start()
    t["now"] += 10.0                      # 10x median -> flagged
    assert wd.step_end(5)
    assert wd.flagged == [5]
    # replay with the same fed durations is bit-identical
    wd2 = fault.StragglerWatchdog(factor=3.0, min_samples=3,
                                  clock=lambda: t["now"])
    for s, d in enumerate([1.0] * 5 + [10.0]):
        wd2.observe(s, d)
    assert wd2.flagged == wd.flagged


def test_run_with_restarts_injectable_sleep():
    """The supervisor's backoff goes through the injected sleep (linear
    in the attempt), so deterministic tests never wall-wait."""
    slept = []

    def main(attempt):
        if attempt < 2:
            raise RuntimeError("boom")
        return attempt

    assert fault.run_with_restarts(main, max_restarts=3,
                                   sleep=slept.append) == 2
    assert slept == pytest.approx([0.1, 0.2])


def test_preemption_guard_flag():
    g = fault.PreemptionGuard()
    assert not g.preempted
    g.request()
    assert g.preempted


def test_run_with_restarts():
    calls = []

    def main(attempt):
        calls.append(attempt)
        if attempt < 2:
            raise RuntimeError("boom")
        return 42

    assert fault.run_with_restarts(main, max_restarts=3) == 42
    assert calls == [0, 1, 2]


def test_crash_restart_resumes_training():
    """Kill a real training run mid-flight; the restart must resume from the
    checkpoint (same CLI, same dir) and finish all steps."""
    with tempfile.TemporaryDirectory() as d:
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        args = [sys.executable, "-m", "repro.launch.train",
                "--arch", "tinyllama-1.1b", "--reduced", "--steps", "30",
                "--batch", "4", "--seq", "64", "--ckpt-dir", d,
                "--ckpt-every", "5", "--log-every", "5"]
        proc = subprocess.Popen(args, env=env, stdout=subprocess.PIPE,
                                stderr=subprocess.STDOUT, text=True)
        # wait until at least one checkpoint lands, then kill hard
        deadline = time.time() + 600
        while time.time() < deadline:
            steps = ckpt.CheckpointManager(d).all_steps()
            if steps:
                break
            if proc.poll() is not None:
                break
            time.sleep(1.0)
        if proc.poll() is None:
            proc.kill()
            proc.wait()
        steps = ckpt.CheckpointManager(d).all_steps()
        assert steps, "no checkpoint was written before the kill"
        # restart: must resume and complete
        out = subprocess.run(args, env=env, capture_output=True, text=True,
                             timeout=900)
        assert out.returncode == 0, out.stdout + out.stderr
        assert "resumed from step" in out.stdout
        assert "done:" in out.stdout


# --- data -------------------------------------------------------------------------

def test_lm_batch_deterministic_and_sharded():
    a = synthetic.lm_batch(100, 8, 32, step=3, seed=1)
    b = synthetic.lm_batch(100, 8, 32, step=3, seed=1)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = synthetic.lm_batch(100, 8, 32, step=4, seed=1)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # per-host disjoint shards
    h0 = synthetic.lm_batch(100, 8, 32, step=3, seed=1, process_index=0,
                            process_count=2)
    h1 = synthetic.lm_batch(100, 8, 32, step=3, seed=1, process_index=1,
                            process_count=2)
    assert h0["tokens"].shape == (4, 32)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_labels_are_shifted_tokens():
    b = synthetic.lm_batch(50, 2, 16, step=0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert b["mask"][:, -1].sum() == 0


def test_shapes_classification_learnable_structure():
    x, y = synthetic.shapes_classification(64, image=16)
    assert x.shape == (64, 16, 16, 3)
    assert set(np.unique(y)) <= {0, 1, 2, 3}
    # classes differ in mean image statistics (the blob)
    m0 = x[y == 0].mean(axis=0)
    m1 = x[y == 1].mean(axis=0) if (y == 1).any() else m0
    assert np.abs(m0 - m1).max() > 0.3
