"""Shared fixtures.

`retrace_sanitizer`: a `repro.analysis.retrace.RetraceSanitizer` that
asserts every declared compile budget at teardown — a test that watches
a jitted entry point fails if the entry point retraced beyond budget,
even if all its own assertions passed.

The session also pins $REPRO_TUNING_CACHE to a nonexistent temp path:
kernel dispatch consults the autotune cache, and a TUNING_gemm.json left
in the repo root by a local bench run must not leak measured winners
into tests (tests that WANT a cache point the env var somewhere real).
"""

import os
import tempfile

import pytest

os.environ.setdefault(
    "REPRO_TUNING_CACHE",
    os.path.join(tempfile.mkdtemp(prefix="repro-test-tuning-"),
                 "absent.json"))


@pytest.fixture
def retrace_sanitizer():
    from repro.analysis.retrace import RetraceSanitizer
    s = RetraceSanitizer()
    yield s
    s.assert_ok()
