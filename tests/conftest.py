"""Shared fixtures.

`retrace_sanitizer`: a `repro.analysis.retrace.RetraceSanitizer` that
asserts every declared compile budget at teardown — a test that watches
a jitted entry point fails if the entry point retraced beyond budget,
even if all its own assertions passed.
"""

import pytest


@pytest.fixture
def retrace_sanitizer():
    from repro.analysis.retrace import RetraceSanitizer
    s = RetraceSanitizer()
    yield s
    s.assert_ok()
