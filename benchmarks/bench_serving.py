"""Serving-engine benchmark: replay a Poisson-ish synthetic arrival trace
through `repro.serving.Engine` and measure throughput + per-request
latency percentiles.

  PYTHONPATH=src python benchmarks/bench_serving.py --smoke
  PYTHONPATH=src python benchmarks/bench_serving.py --arch mamba2-370m \
      --requests 32 --rate 0.25 --capacity 4

Arrivals are exponential inter-arrival times in engine ticks (one decode
step = one tick), so traces are deterministic and replayable; wall-clock
metrics come from the engine's per-request timestamps.  Writes a JSON
report (default BENCH_serving.json) for the bench trajectory; `--smoke`
runs a tiny trace on the reduced config — wired into CI so the engine's
hot path is exercised on every PR.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import numpy as np

from repro import configs
from repro.serving import Engine, Request, SamplingParams


def build_trace(cfg, n_requests: int, rate: float, prompt_lo: int,
                prompt_hi: int, gen_lo: int, gen_hi: int, seed: int,
                mixed_sampling: bool) -> list[Request]:
    """Heterogeneous prompt lengths, arrivals, and sampling params."""
    rng = np.random.default_rng(seed)
    t = 0.0
    reqs = []
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / max(rate, 1e-9)))
        n = int(rng.integers(prompt_lo, prompt_hi + 1))
        gen = int(rng.integers(gen_lo, gen_hi + 1))
        if mixed_sampling and i % 3 == 1:
            sp = SamplingParams(temperature=0.8, top_k=16,
                                max_new_tokens=gen, seed=1000 + i)
        elif mixed_sampling and i % 3 == 2:
            sp = SamplingParams(temperature=1.2, max_new_tokens=gen,
                                seed=2000 + i)
        else:
            sp = SamplingParams(max_new_tokens=gen)      # greedy
        reqs.append(Request(f"req{i:03d}",
                            rng.integers(0, cfg.vocab, (n,)).tolist(),
                            sp, arrival=t))
    return reqs


def build_named_trace(name: str, cfg, args) -> list[Request]:
    """Deterministic request sets for the slot-vs-paged comparison."""
    if name == "standard":
        return build_trace(cfg, args.requests, args.rate, args.prompt_min,
                           args.prompt_max, args.gen_min, args.gen_max,
                           args.seed, not args.uniform_sampling)
    rng = np.random.default_rng(args.seed + {"long-prompt": 101,
                                             "shared-prefix": 202,
                                             "burst": 303}[name])
    n = args.requests
    reqs: list[Request] = []

    def sp(i, gen):
        if not args.uniform_sampling and i % 3 == 1:
            return SamplingParams(temperature=0.8, top_k=16,
                                  max_new_tokens=gen, seed=1000 + i)
        return SamplingParams(max_new_tokens=gen)      # greedy

    def prompt(k):
        return rng.integers(0, cfg.vocab, (k,)).tolist()

    def gen():
        return int(rng.integers(args.gen_min, args.gen_max + 1))

    if name == "long-prompt":
        # a few near-max prompts with LONG generations hog slots while a
        # stream of short requests arrives behind them: the whole-slot
        # engine reserves a full max_len row per request, the paged
        # engine admits by actual page need, so shorts queue far less
        long_gen = min(2 * args.gen_max, args.max_len // 4)
        long_lens = [args.max_len - long_gen - 2,
                     args.max_len // 2 - 2]
        t = 0.0
        for i in range(n):
            if i < max(2, n // 4):
                k, g = long_lens[i % len(long_lens)], long_gen
            else:
                k = int(rng.integers(args.prompt_min, args.prompt_min + 3))
                g = gen()
            reqs.append(Request(f"lp{i:03d}", prompt(k), sp(i, g),
                                arrival=t))
            t += float(rng.exponential(1.0 / max(args.rate, 1e-9)))
    elif name == "shared-prefix":
        # request groups share a long system prefix: the paged engine
        # serves the shared pages from the prefix cache (tail-only
        # prefill); the whole-slot engine re-prefills every time
        shared = prompt(args.max_len // 2)
        for i in range(n):
            tail = prompt(int(rng.integers(2, 6)))
            reqs.append(Request(f"sp{i:03d}", shared + tail, sp(i, gen()),
                                arrival=float(i) * 0.5))
    elif name == "burst":
        # everything lands at tick 0: pure admission-queue pressure
        # (mid-length prompts, so the paged pool fits its extra lanes),
        # drained faster by speculation
        for i in range(n):
            k = int(rng.integers(args.prompt_min,
                                 args.max_len // 2 - 2))
            reqs.append(Request(f"bu{i:03d}", prompt(k), sp(i, gen()),
                                arrival=0.0))
    else:
        raise ValueError(f"unknown trace {name!r}")
    return reqs


def run_comparison(cfg, args, trace_names, mesh):
    """Slot engine vs paged+chunked+speculative engine on shared params
    and identical traces, at EQUAL KV MEMORY: the slot engine reserves
    `capacity` full max_len rows; the paged engine gets the same pool of
    KV tokens as pages and twice the decode lanes, admitting by actual
    page need.  Per-trace latency metrics (wall + deterministic
    tick-space TTFT), token identity (asserted — the differential
    invariant rides in the bench), and aggregate speculation counters."""
    import jax

    from repro.models import api
    from repro.serving import PagedEngine

    params = api.init_params(cfg, jax.random.key(0))
    kv_pool_tokens = args.capacity * args.max_len
    paged_capacity = 2 * args.capacity
    paged_kw = dict(page_size=args.page_size,
                    n_pages=kv_pool_tokens // args.page_size + 1,
                    prefill_chunk=args.prefill_chunk,
                    chunk_budget=(args.chunk_budget
                                  or max(1, args.max_len
                                         // args.prefill_chunk)),
                    spec_k=args.spec_k,
                    draft_tier=args.draft_tier or None)
    out = {"page_size": args.page_size,
           "prefill_chunk": args.prefill_chunk,
           "spec_k": args.spec_k,
           "draft_tier": args.draft_tier or None,
           "slot_capacity": args.capacity,
           "paged_capacity": paged_capacity,
           "kv_pool_tokens": kv_pool_tokens,
           "traces": {}}
    spec_tot = {"proposed": 0, "accepted": 0, "corrections": 0}
    spec_steps = 0
    retrace_ok = True
    for name in trace_names:
        reqs = build_named_trace(name, cfg, args)
        rows, toks = {}, {}
        entry: dict = {"requests": len(reqs)}
        for kind in ("slot", "paged"):
            if kind == "slot":
                eng = Engine(cfg, params, capacity=args.capacity,
                             max_len=args.max_len, seed=args.seed,
                             mesh=mesh)
            else:
                eng = PagedEngine(cfg, params, capacity=paged_capacity,
                                  max_len=args.max_len, seed=args.seed,
                                  mesh=mesh, **paged_kw)
            sanitizer = None
            if args.sanitize_retrace:
                from repro.analysis.retrace import instrument_engine
                sanitizer = instrument_engine(eng)
            # identical warmup protocol for both engines: one multi-chunk
            # greedy request (warms prefill/chunk/draft/verify) plus one
            # sampled request (warms the non-speculative decode path)
            wl = max(args.prompt_min, args.prefill_chunk + 2)
            eng.submit(Request("_warm_g", [1] * wl,
                               SamplingParams(max_new_tokens=2)))
            eng.submit(Request("_warm_s", [1] * args.prompt_min,
                               SamplingParams(temperature=0.8, top_k=8,
                                              max_new_tokens=2, seed=7)))
            eng.run_until_complete()
            base_decode_s = eng.stats()["decode_s"]
            t0 = time.perf_counter()
            start = eng.tick
            for r in reqs:
                eng.submit(dataclasses.replace(
                    r, arrival=r.arrival + start))
            done = [c for c in eng.run_until_complete()
                    if not c.request_id.startswith("_warm")]
            wall = time.perf_counter() - t0
            toks[kind] = {c.request_id: c.tokens for c in done}
            ttft = np.asarray([c.ttft_s for c in done])
            ticks = np.asarray([c.ttft_ticks for c in done])
            lat = np.asarray([c.latency_s for c in done])
            st = eng.stats()
            decode_toks = sum(len(c.tokens) - 1 for c in done)
            rows[kind] = {
                "wall_s": wall,
                "ttft_p50_s": float(np.percentile(ttft, 50)),
                "ttft_p95_s": float(np.percentile(ttft, 95)),
                "ttft_p50_ticks": float(np.percentile(ticks, 50)),
                "ttft_p95_ticks": float(np.percentile(ticks, 95)),
                "latency_p95_s": float(np.percentile(lat, 95)),
                "decode_tokens_per_s": decode_toks / max(
                    st["decode_s"] - base_decode_s, 1e-9),
            }
            if sanitizer is not None:
                finds = sanitizer.findings()
                entry[f"{kind}_retrace_ok"] = not finds
                retrace_ok &= not finds
                for f_ in finds:
                    print(f"[bench_serving]   {name}/{kind}: "
                          f"{f_.render()}")
            if kind == "paged":
                pst = st["paged"]
                entry["alloc"] = {k: pst[k] for k in
                                  ("n_pages", "pages_live", "prefix_hits",
                                   "prefix_hit_tokens", "cow_copies",
                                   "alloc_failures")}
                entry["chunks"] = pst["chunked"]["chunks"]
                if "spec" in st:
                    entry["spec"] = st["spec"]
                    for k in spec_tot:
                        spec_tot[k] += st["spec"][k]
                    spec_steps += st["spec"]["steps"]
        entry["tokens_match"] = toks["slot"] == toks["paged"]
        assert entry["tokens_match"], (
            name, {r: (toks["slot"][r], toks["paged"].get(r))
                   for r in toks["slot"]
                   if toks["slot"][r] != toks["paged"].get(r)})
        entry["slot"], entry["paged"] = rows["slot"], rows["paged"]
        entry["ttft_p95_improvement"] = (
            rows["slot"]["ttft_p95_s"]
            / max(rows["paged"]["ttft_p95_s"], 1e-9))
        entry["ttft_p95_ticks_improvement"] = (
            rows["slot"]["ttft_p95_ticks"]
            / max(rows["paged"]["ttft_p95_ticks"], 1e-9))
        out["traces"][name] = entry
        print(f"[bench_serving] trace {name}: tokens MATCH, ttft p95 "
              f"slot {rows['slot']['ttft_p95_ticks']:.1f} vs paged "
              f"{rows['paged']['ttft_p95_ticks']:.1f} ticks "
              f"({entry['ttft_p95_ticks_improvement']:.1f}x; wall "
              f"{entry['ttft_p95_improvement']:.1f}x), decode "
              f"{rows['slot']['decode_tokens_per_s']:.0f} vs "
              f"{rows['paged']['decode_tokens_per_s']:.0f} tok/s")
    spec = None
    if args.draft_tier:
        spec = {"draft_tier": args.draft_tier, "k": args.spec_k,
                "steps": spec_steps, **spec_tot,
                "acceptance_rate": (spec_tot["accepted"]
                                    / spec_tot["proposed"]
                                    if spec_tot["proposed"] else 0.0)}
    return out, spec, retrace_ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mult", default="")
    ap.add_argument("--kernel-policy", default="",
                    choices=["", "auto", "pallas", "xla"])
    ap.add_argument("--mesh", default="",
                    help="device mesh spec, e.g. 'model=4,data=2' "
                         "(default: $REPRO_MESH, then the host mesh)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=0.5,
                    help="mean arrivals per engine tick")
    ap.add_argument("--capacity", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--prompt-min", type=int, default=8)
    ap.add_argument("--prompt-max", type=int, default=48)
    ap.add_argument("--gen-min", type=int, default=4)
    ap.add_argument("--gen-max", type=int, default=16)
    ap.add_argument("--uniform-sampling", action="store_true",
                    help="all-greedy trace (default mixes sampling params)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--meter", action="store_true",
                    help="serve with a repro.fleet EnergyMeter attached: "
                         "adds metrics.energy_j / co2e_g / "
                         "co2e_g_per_token and per-request carbon")
    ap.add_argument("--region", default="us-east",
                    help="grid region for --meter intensity")
    ap.add_argument("--trace", action="append", default=None,
                    choices=["standard", "long-prompt", "shared-prefix",
                             "burst"],
                    help="run a slot-vs-paged differential comparison on "
                         "this named trace (repeatable); populates "
                         "report['paged'] / report['spec']")
    ap.add_argument("--paged", action="store_true",
                    help="shorthand for --trace standard")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV page size (tokens) for the paged engine")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="chunked-prefill chunk length for the paged "
                         "engine")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft length for speculative decoding")
    ap.add_argument("--chunk-budget", type=int, default=0,
                    help="prefill chunks per engine tick (0 = enough "
                         "for one full max_len prompt per tick)")
    ap.add_argument("--draft-tier", default="exact",
                    help="draft tier for speculative decoding in the "
                         "paged comparison ('' disables; a mult name "
                         "like trunc4x4 drafts approximately and "
                         "verifies exactly)")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace on the reduced config (CI)")
    ap.add_argument("--sanitize-retrace", action="store_true",
                    help="watch the engine's jitted phases under the "
                         "repro.analysis compile budgets (decode compiles "
                         "once, prefill once per bucket) and fail the "
                         "bench on any violation")
    args = ap.parse_args(argv)

    if args.smoke:
        args.reduced = True
        args.requests = min(args.requests, 8)
        args.capacity = 3
        args.max_len = 64
        args.prompt_min, args.prompt_max = 6, 24
        args.gen_min, args.gen_max = 3, 6
        args.page_size, args.prefill_chunk, args.spec_k = 8, 8, 3

    cfg = configs.apply_overrides(configs.get_config(args.arch),
                                  reduced=args.reduced, mult=args.mult,
                                  kernel_policy=args.kernel_policy)
    reqs = build_trace(cfg, args.requests, args.rate, args.prompt_min,
                       args.prompt_max, args.gen_min, args.gen_max,
                       args.seed, not args.uniform_sampling)

    from repro.launch.mesh import make_mesh_from_spec
    meter = None
    if args.meter:
        from repro.fleet import DevicePowerModel, EnergyMeter, StaticGrid
        meter = EnergyMeter(power=DevicePowerModel(),
                            grid=StaticGrid(args.region))
    mesh = make_mesh_from_spec(args.mesh)
    eng = Engine(cfg, capacity=args.capacity, max_len=args.max_len,
                 seed=args.seed, mesh=mesh, meter=meter)
    sanitizer = None
    if args.sanitize_retrace:
        # budgets count from here, so the warmup compiles are the ONLY
        # compiles allowed: decode exactly once, prefill once per bucket
        from repro.analysis.retrace import instrument_engine
        sanitizer = instrument_engine(eng)
    # warm the jitted prefill/insert/decode once so the trace's latency
    # percentiles measure steady-state serving, not compile time
    eng.submit(Request("_warmup", [1] * args.prompt_min,
                       SamplingParams(max_new_tokens=2)))
    eng.run_until_complete()
    base = eng.stats()

    t0 = time.perf_counter()
    start_tick = eng.tick
    for r in reqs:
        # trace arrivals are relative to the start of the measured run
        eng.submit(dataclasses.replace(r, arrival=r.arrival + start_tick))
    done = [c for c in eng.run_until_complete()
            if c.request_id != "_warmup"]
    wall_s = time.perf_counter() - t0

    assert len(done) == args.requests, (len(done), args.requests)
    stats = eng.stats()
    stats["prefill_s"] -= base["prefill_s"]
    stats["decode_s"] -= base["decode_s"]
    stats["completed"] -= base["completed"]
    stats["queue_wait_ticks_total"] -= base["queue_wait_ticks_total"]
    stats["queue_wait_ticks_mean"] = (
        stats["queue_wait_ticks_total"] / max(stats["completed"], 1))
    stats["evictions"] = {k: v - base["evictions"].get(k, 0)
                          for k, v in stats["evictions"].items()}
    lat = np.asarray([c.latency_s for c in done])
    ttft = np.asarray([c.ttft_s for c in done])
    total_toks = sum(len(c.tokens) for c in done)
    decode_toks = sum(len(c.tokens) - 1 for c in done)
    report = {
        "bench": "serving",
        "arch": cfg.name,
        "family": cfg.family,
        "mult": cfg.mult or "exact",
        "reduced": args.reduced,
        "trace": {
            "requests": args.requests, "rate_per_tick": args.rate,
            "capacity": args.capacity, "max_len": args.max_len,
            "prompt_len": [args.prompt_min, args.prompt_max],
            "gen_len": [args.gen_min, args.gen_max],
            "mixed_sampling": not args.uniform_sampling,
            "seed": args.seed,
        },
        "mesh": stats["mesh"],
        "metrics": {
            "wall_s": wall_s,
            "total_tokens": total_toks,
            "tokens_per_s": total_toks / max(wall_s, 1e-9),
            "decode_tokens_per_s":
                decode_toks / max(stats["decode_s"], 1e-9),
            "latency_p50_s": float(np.percentile(lat, 50)),
            "latency_p95_s": float(np.percentile(lat, 95)),
            "ttft_p50_s": float(np.percentile(ttft, 50)),
            "ttft_p95_s": float(np.percentile(ttft, 95)),
            "ttft_mean_s": float(np.mean(ttft)),
            "mean_queue_ticks": float(np.mean(
                [c.admitted_tick - c.arrival for c in done])),
        },
        "engine": stats,
    }
    if meter is not None:
        # per-request attribution over the measured trace (the engine's
        # cumulative counters in stats["carbon"] also include warmup)
        energy_j = sum(c.carbon.energy_j for c in done)
        co2e_g = sum(c.carbon.co2e_g for c in done)
        report["metrics"]["energy_j"] = energy_j
        report["metrics"]["co2e_g"] = co2e_g
        report["metrics"]["co2e_g_per_token"] = co2e_g / max(total_toks, 1)
        report["metrics"]["energy_j_per_token"] = (
            energy_j / max(total_toks, 1))
        report["carbon"] = {"region": meter.region,
                            "g_per_kwh": meter.g_per_kwh_now(),
                            "power": stats["carbon"]["power"]}
    trace_names = list(dict.fromkeys(
        (["standard"] if args.paged else []) + (args.trace or [])))
    cmp_retrace_ok = True
    if trace_names:
        paged_rep, spec_rep, cmp_retrace_ok = run_comparison(
            cfg, args, trace_names, mesh)
        report["paged"] = paged_rep
        if spec_rep is not None:
            report["spec"] = spec_rep
    retrace_findings = []
    if sanitizer is not None:
        retrace_findings = sanitizer.findings()
        report["retrace"] = {
            "ok": not retrace_findings,
            "findings": [f.render() for f in retrace_findings],
            "watches": sanitizer.report(),
        }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    m = report["metrics"]
    mesh_str = ",".join(f"{k}={v}" for k, v in report["mesh"].items())
    print(f"[bench_serving] {cfg.name} ({cfg.mult or 'exact'}, "
          f"mesh {mesh_str}): {args.requests} reqs in {wall_s:.2f}s, "
          f"{m['tokens_per_s']:.1f} tok/s "
          f"(decode {m['decode_tokens_per_s']:.1f}), "
          f"latency p50 {m['latency_p50_s'] * 1e3:.0f}ms "
          f"p95 {m['latency_p95_s'] * 1e3:.0f}ms, "
          f"ttft p50 {m['ttft_p50_s'] * 1e3:.0f}ms "
          f"p95 {m['ttft_p95_s'] * 1e3:.0f}ms -> {args.out}")
    if meter is not None:
        print(f"[bench_serving] carbon ({meter.region}): "
              f"{m['energy_j']:.2f} J, {m['co2e_g']:.3e} gCO2e, "
              f"{m['co2e_g_per_token']:.3e} g/token")
    if sanitizer is not None:
        compiles = {n: w["compiles"]
                    for n, w in sanitizer.report().items()}
        print(f"[bench_serving] retrace sanitizer: "
              f"{'OK' if not retrace_findings else 'FAIL'} {compiles}")
        for f_ in retrace_findings:
            print(f"[bench_serving]   {f_.render()}")
        if retrace_findings:
            return 1
    if "spec" in report:
        s = report["spec"]
        print(f"[bench_serving] spec (draft {s['draft_tier']}, "
              f"k={s['k']}): {s['proposed']} proposed, "
              f"{s['accepted']} accepted "
              f"({s['acceptance_rate']:.2f}), "
              f"{s['corrections']} corrections")
    if not cmp_retrace_ok:
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
