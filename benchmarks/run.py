"""Benchmark harness: one module per paper table/figure + substrate
microbenches.  Prints ``name,us_per_call,derived`` CSV lines.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig2       # filter by prefix
"""

from __future__ import annotations

import sys
import time
import traceback

from benchmarks import (bench_accuracy, bench_codesign, bench_gemm,
                        bench_kernels, beyond_lm_codesign,
                        fig2_table_reduction, fig2_vgg16_tradeoff,
                        fig3_cross_models)

SUITES = [
    ("fig2_vgg16_tradeoff", fig2_vgg16_tradeoff.main),
    ("fig2_table_reduction", fig2_table_reduction.main),
    ("fig3_cross_models", fig3_cross_models.main),
    ("bench_gemm", bench_gemm.csv_main),
    ("bench_codesign", bench_codesign.csv_main),
    ("bench_kernels", bench_kernels.main),
    ("bench_accuracy", bench_accuracy.main),
    ("beyond_lm_codesign", beyond_lm_codesign.main),
]


def main() -> int:
    filt = sys.argv[1] if len(sys.argv) > 1 else ""
    print("name,us_per_call,derived")
    failed = 0
    for name, fn in SUITES:
        if filt and not name.startswith(filt):
            continue
        t0 = time.time()
        try:
            for line in fn():
                print(line)
            print(f"# {name} done in {time.time() - t0:.1f}s",
                  file=sys.stderr)
        except Exception:
            failed += 1
            print(f"# {name} FAILED", file=sys.stderr)
            traceback.print_exc()
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
