"""Accuracy-drop calibration (the ApproxTrain step of the methodology):
train a small CNN on the synthetic shapes task, then measure real top-1
accuracy under each approximate multiplier.  This grounds the GA's
NMED->drop proxy (core/ga.py) in measured data."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.approx import gemm as G
from repro.core import ga as gamod
from repro.core import multipliers as mm
from repro.data import synthetic
from repro.models import cnn


N_CLASSES = 8
TASK = dict(image=32, n_classes=N_CLASSES, amplitude=0.9, noise=0.55)


def train_small_cnn(steps: int = 260, seed: int = 0):
    x, y = synthetic.shapes_classification(512, seed=seed, **TASK)
    xt, yt = jnp.asarray(x), jnp.asarray(y)
    params = cnn.init_vgg("vgg_mini", jax.random.key(seed),
                          n_classes=N_CLASSES, image=32)

    def loss(p, xb, yb):
        logits = cnn.vgg_forward(p, xb, "vgg_mini")
        onehot = jax.nn.one_hot(yb, N_CLASSES)
        return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))

    @jax.jit
    def step(p, xb, yb, lr):
        l, g = jax.value_and_grad(loss)(p, xb, yb)
        p = jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g)
        return p, l

    rng = np.random.default_rng(seed)
    for s in range(steps):
        idx = rng.integers(0, 512, 64)
        params, l = step(params, xt[idx], yt[idx], jnp.asarray(0.05))
    return params


def accuracy(params, spec, seed=1) -> float:
    x, y = synthetic.shapes_classification(512, seed=seed, **TASK)
    logits = cnn.vgg_forward(params, jnp.asarray(x), "vgg_mini", spec=spec)
    return float((jnp.argmax(logits, -1) == jnp.asarray(y)).mean())


def main() -> list[str]:
    t0 = time.time()
    params = train_small_cnn()
    base = accuracy(params, None)
    lines = [f"accuracy_exact,{(time.time() - t0) * 1e6:.0f},"
             f"top1={base:.4f}"]
    for name in ("trunc1x1", "trunc2x2", "trunc3x3", "trunc4x4"):
        mobj = mm.get_multiplier(name)
        spec = G.from_multiplier(mobj)
        t0 = time.time()
        acc = accuracy(params, spec)
        drop = 100 * (base - acc)
        proxy = gamod.proxy_accuracy_drop(mobj)
        lines.append(
            f"accuracy_{name},{(time.time() - t0) * 1e6:.0f},"
            f"top1={acc:.4f};drop_pct={drop:.2f};proxy_pct={proxy:.2f};"
            f"nmed={mobj.stats.nmed:.5f}")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
