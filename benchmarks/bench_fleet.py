"""Fleet benchmark: carbon-aware routing + failover under a time-varying
grid, with metering on — the operational half of the total-carbon story.

  PYTHONPATH=src python benchmarks/bench_fleet.py --smoke
  PYTHONPATH=src python benchmarks/bench_fleet.py --requests 24 \
      --regions us-west,eu-west --kill 6
  PYTHONPATH=src python benchmarks/bench_fleet.py --smoke --chaos

Replays a Poisson trace through a 2+ replica `repro.fleet` router
(diurnal per-region grid traces by default), kills one replica mid-trace
(`--kill`, on by default — the failover invariants are part of the
schema), and writes BENCH_fleet.json: per-replica energy/CO2e, routed
shares, the low-carbon routing share, SLO attainment, and the zero-lost
failover accounting.  `--sanitize-retrace` watches every replica
engine's jitted phases under the repro.analysis compile budgets.

`--chaos` additionally runs two deterministic chaos campaigns on
tier-laddered fleets (`--tiers`) and records a `chaos` section:

  * a seeded `ChaosSchedule.random(--chaos-seed)` campaign (transient
    crashes with recovery, submission-boundary deaths, stragglers, grid
    spikes, bursts) whose invariant checkers — zero lost, exactly-once,
    meter conservation, deadline accounting, monotone tiers — must all
    pass;
  * a burst-overload A/B: the same flood with and without the
    `DegradationController`, showing brownout holding p95 TTFT within
    the (tight) `--brownout-slo-ticks` by shifting tokens onto approx
    tiers, then restoring exact after the burst drains.
"""

from __future__ import annotations

import argparse
import dataclasses
import json

from repro import configs
from repro.launch.fleet import build_fleet, poisson_requests, ttft_ticks


def _run_chaos(cfg, args, regions, max_len) -> tuple[dict, bool]:
    """Two deterministic campaigns on tier-laddered fleets; returns the
    `chaos` report section and whether every gate passed."""
    import random

    from repro.fleet.chaos import ChaosCampaign, ChaosSchedule, _p95
    from repro.fleet.router import DegradationConfig, FleetConfig
    from repro.serving import Request, SamplingParams

    tiers = tuple(t.strip() for t in args.tiers.split(",") if t.strip())

    def fresh(slo_ticks, degradation):
        return build_fleet(
            cfg, regions=regions, trace=args.trace, capacity=args.capacity,
            max_len=max_len, seed=args.seed,
            seconds_per_tick=args.seconds_per_tick, tiers=tiers,
            fleet_cfg=FleetConfig(ttft_slo_ticks=slo_ticks,
                                  degradation=degradation))

    # ---- seeded fault campaign: the invariant gauntlet -------------------
    fleet = fresh(args.slo_ticks, DegradationConfig(patience=1))
    trace = [dataclasses.replace(r,
                                 ttft_deadline_ticks=4.0 * args.slo_ticks,
                                 deadline_ticks=8.0 * args.slo_ticks)
             for r in poisson_requests(args.requests, args.prompt_len,
                                       args.gen, cfg.vocab, seed=args.seed)]
    schedule = ChaosSchedule.random(args.chaos_seed,
                                    [r.name for r in fleet.replicas])
    campaign = ChaosCampaign(fleet, trace, schedule).run()

    # ---- brownout A/B: same burst flood with/without the controller ------
    bslo = args.brownout_slo_ticks
    rng = random.Random(args.chaos_seed)
    flood = [Request(request_id=f"burst{i}",
                     tokens=[rng.randrange(1, cfg.vocab)
                             for _ in range(args.prompt_len)],
                     sampling=SamplingParams(max_new_tokens=args.gen),
                     arrival=2.0)
             for i in range(args.brownout_requests)]

    def run_flood(degradation):
        f = fresh(bslo, degradation)
        for r in flood:
            f.submit(r)
        f.run_until_complete()
        for _ in range(48):     # cooldown: let the controller restore exact
            f.step()
        rb = f.stats()["robustness"]
        return {
            # wall-clock TTFT (fleet ticks): degraded tiers run several
            # engine ticks per fleet tick, so only the wall metric can
            # show the brownout holding the SLO
            "ttft_p95_ticks": _p95(list(f.wall_ttft_ticks().values())),
            "tier_occupancy": f.tier_occupancy(),
            "degradation_events": len(rb["degradation_events"]),
            "final_tiers": {r.name: r.engine.tier for r in f.replicas},
        }

    with_ctl = run_flood(DegradationConfig(patience=1))
    without_ctl = run_flood(None)
    brownout = {
        "requests": args.brownout_requests,
        "slo_ticks": bslo,
        "with_controller": with_ctl,
        "without_controller": without_ctl,
        "holds_slo": with_ctl["ttft_p95_ticks"] <= bslo,
        "improves_p95": (with_ctl["ttft_p95_ticks"]
                         < without_ctl["ttft_p95_ticks"]),
        "restored_exact": all(t == tiers[0]
                              for t in with_ctl["final_tiers"].values()),
    }
    section = {
        "seed": args.chaos_seed,
        "tiers": list(tiers),
        "campaign": campaign.to_dict(),
        "brownout": brownout,
    }
    ok = (campaign.ok and brownout["holds_slo"]
          and brownout["improves_p95"] and brownout["restored_exact"])
    return section, ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--regions", default="us-west,eu-west")
    ap.add_argument("--trace", default="diurnal",
                    choices=["static", "diurnal"])
    ap.add_argument("--capacity", type=int, default=2)
    ap.add_argument("--slo-ticks", type=float, default=32.0)
    ap.add_argument("--seconds-per-tick", type=float, default=1800.0)
    ap.add_argument("--kill", type=int, default=5,
                    help="inject a replica-0 fault after this many of its "
                         "steps (-1 disables; the schema's failover "
                         "checks need a kill)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_fleet.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace on the reduced config (CI)")
    ap.add_argument("--sanitize-retrace", action="store_true",
                    help="watch every replica engine's jitted phases "
                         "under the repro.analysis compile budgets")
    ap.add_argument("--chaos", action="store_true",
                    help="also run the seeded chaos campaign + brownout "
                         "A/B on tier-laddered fleets and record a "
                         "'chaos' report section")
    ap.add_argument("--chaos-seed", type=int, default=7)
    ap.add_argument("--tiers", default="exact,trunc2x2,trunc4x4",
                    help="comma-separated multiplier tier ladder for the "
                         "chaos fleets (index 0 = most accurate)")
    ap.add_argument("--brownout-requests", type=int, default=24)
    ap.add_argument("--brownout-slo-ticks", type=float, default=24.0,
                    help="tight TTFT SLO for the burst-overload A/B "
                         "(chosen so only the degraded ladder holds it)")
    args = ap.parse_args(argv)

    if args.smoke:
        args.reduced = True
        args.requests = min(args.requests, 12)
        args.gen = min(args.gen, 6)

    cfg = configs.apply_overrides(configs.get_config(args.arch),
                                  reduced=args.reduced)
    regions = tuple(args.regions.split(","))
    max_len = args.prompt_len + args.gen + 8
    fleet = build_fleet(cfg, regions=regions, trace=args.trace,
                        capacity=args.capacity, max_len=max_len,
                        seed=args.seed, ttft_slo_ticks=args.slo_ticks,
                        seconds_per_tick=args.seconds_per_tick)

    sanitizers = {}
    if args.sanitize_retrace:
        # one sanitizer per engine: watch names are per-engine-phase, so
        # replicas must not share a sanitizer
        from repro.analysis.retrace import instrument_engine
        for rep in fleet.replicas:
            sanitizers[rep.name] = instrument_engine(rep.engine)

    for r in poisson_requests(args.requests, args.prompt_len, args.gen,
                              cfg.vocab, seed=args.seed):
        fleet.submit(r)
    killed = []
    if args.kill >= 0:
        fleet.replicas[0].inject_fault(at_step=args.kill)
        killed.append(fleet.replicas[0].name)
    comps = fleet.run_until_complete()
    s = fleet.stats()

    tt = sorted(ttft_ticks(c) for c in comps)
    p95 = tt[min(int(0.95 * len(tt)), len(tt) - 1)] if tt else 0
    routed_share = {name: n / max(s["submitted"] + s["requeued"], 1)
                    for name, n in s["routed"].items()}
    report = {
        "bench": "fleet",
        "arch": cfg.name,
        "reduced": args.reduced,
        "trace": {
            "requests": args.requests, "regions": list(regions),
            "grid": args.trace, "capacity": args.capacity,
            "prompt_len": args.prompt_len, "gen": args.gen,
            "seconds_per_tick": args.seconds_per_tick,
            "seed": args.seed, "ticks": s["ticks"],
        },
        "replicas": s["replicas"],
        "routing": {
            "low_carbon_share": s["low_carbon_share"],
            "routed": s["routed"],
            "routed_share": routed_share,
        },
        "failover": {
            "killed": killed,
            "kill_at_step": args.kill,
            "requeued": s["requeued"],
            "requeue_events": s["requeue_events"],
            "lost": len(s["lost"]),
        },
        "slo": {
            "ttft_slo_ticks": args.slo_ticks,
            "ttft_p50_ticks": tt[len(tt) // 2] if tt else 0,
            "ttft_p95_ticks": p95,
            "ok": p95 <= args.slo_ticks,
        },
        "totals": {
            "submitted": s["submitted"], "completed": s["completed"],
            **s["totals"],
        },
    }
    chaos_ok = True
    if args.chaos:
        report["chaos"], chaos_ok = _run_chaos(cfg, args, regions, max_len)
    if sanitizers:
        findings = [f for sz in sanitizers.values() for f in sz.findings()]
        report["retrace"] = {
            "ok": not findings,
            "findings": [f.render() for f in findings],
            "watches": {f"{name}/{w}": v
                        for name, sz in sanitizers.items()
                        for w, v in sz.report().items()},
        }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)

    t = report["totals"]
    print(f"[bench_fleet] {len(regions)} replicas ({args.trace} grid), "
          f"{s['submitted']} reqs, kill={killed or 'off'}: "
          f"requeued={s['requeued']} lost={len(s['lost'])}, "
          f"low-carbon share {s['low_carbon_share']:.2f}, "
          f"ttft p95 {p95} ticks (slo {args.slo_ticks:.0f})")
    print(f"[bench_fleet] {t['energy_j']:.2f} J, {t['co2e_g']:.3e} gCO2e, "
          f"{t['co2e_g_per_token']:.3e} g/token -> {args.out}")
    if args.chaos:
        c = report["chaos"]
        camp, brn = c["campaign"], c["brownout"]
        print(f"[bench_fleet] chaos campaign (seed {c['seed']}): "
              f"{'OK' if camp['ok'] else 'VIOLATED'} — "
              f"faults={camp['faults_by_kind']} "
              f"recoveries={camp['recoveries']} "
              f"max_attempt={camp['max_attempt']} lost={camp['lost']}")
        for v in camp["violations"]:
            print(f"[bench_fleet]   violation: {v}")
        wc, wo = brn["with_controller"], brn["without_controller"]
        print(f"[bench_fleet] brownout A/B (slo {brn['slo_ticks']:.0f}): "
              f"p95 {wc['ttft_p95_ticks']:.0f} w/ controller vs "
              f"{wo['ttft_p95_ticks']:.0f} without — "
              f"holds_slo={brn['holds_slo']} "
              f"restored_exact={brn['restored_exact']} "
              f"occupancy={wc['tier_occupancy']}")
    if sanitizers:
        print(f"[bench_fleet] retrace sanitizer: "
              f"{'OK' if report['retrace']['ok'] else 'FAIL'}")
        for msg in report["retrace"]["findings"]:
            print(f"[bench_fleet]   {msg}")
        if not report["retrace"]["ok"]:
            return 1
    if not chaos_ok:
        return 1
    return 0 if not s["lost"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
