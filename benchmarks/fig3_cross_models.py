"""Paper Fig. 3: embodied carbon across DNN models (VGG16/19, ResNet50/152)
x technology nodes, three designs each (normalized to the exact baseline):
  exact @ 30 FPS   |   approx-only (<=2 % drop)   |   GA-CDP.

Paper's claim: GA-CDP saves up to 65 % (VGG16) and 30-70 % across models.
"""

from __future__ import annotations

import time

from repro.core import codesign, ga, multipliers as mm, pareto

MODELS = ("vgg16", "vgg19", "resnet50", "resnet152")


def rows() -> list[dict]:
    mults = pareto.default_front() + list(mm.static_library().values())
    out = []
    for model in MODELS:
        for node in (7, 14, 28):
            rep = codesign.run_codesign(
                model, node, 30.0, 2.0, mults=mults,
                ga_cfg=ga.GAConfig(pop_size=24, generations=12, seed=0))
            base = rep.exact.carbon_g
            out.append({
                "model": model, "node_nm": node,
                "exact_norm": 1.0,
                "approx_norm": round(rep.approx_only.carbon_g / base, 4),
                "ga_cdp_norm": round(rep.ga_cdp.carbon_g / base, 4),
                "ga_saving_pct": round(100 * rep.ga_reduction, 2),
                "exact_pes": rep.exact.config.num_pes,
                "ga_pes": rep.ga_cdp.config.num_pes,
                "ga_mult": rep.ga_cdp.config.multiplier,
                "ga_fps": round(rep.ga_cdp.fps, 1),
            })
    return out


def main() -> list[str]:
    t0 = time.time()
    rs = rows()
    us = (time.time() - t0) * 1e6 / max(len(rs), 1)
    return [
        "fig3_cross_models,{:.1f},{}".format(
            us, ";".join(f"{k}={v}" for k, v in r.items()))
        for r in rs
    ]


if __name__ == "__main__":
    print("\n".join(main()))
