"""ApproxTrain-substrate throughput: approximate-GEMM modes vs the exact
LUT oracle (the tool-paper [8] comparison).  CPU timings are indicative
(interpret-mode kernels); the structural result is the op-count ratio:
lowrank rank-R costs (R+1) int8 matmuls vs the oracle's O(mkn) gather."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.approx import gemm as G
from repro.core import multipliers as mm, netlist as nl
from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def main() -> list[str]:
    rng = np.random.default_rng(0)
    m, k, n = 256, 512, 256
    a = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
    b = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
    mask = rng.random(len(nl.bw8().prunable_gates())) < 0.03
    pruned = mm.pruned(mask, name="bench_pruned")
    lines = []
    cases = [
        ("exact", G.from_multiplier(mm.exact_multiplier())),
        ("trunc2x2", G.from_multiplier(mm.truncated(2, 2))),
        ("lowrank_r2", G.from_multiplier(pruned, rank=2)),
        ("lowrank_r4", G.from_multiplier(pruned, rank=4)),
        ("lowrank_r8", G.from_multiplier(pruned, rank=8)),
    ]
    f_or = jax.jit(lambda x, y: ref.lut_matmul(x, y,
                                               jnp.asarray(pruned.lut)))
    us_oracle = _time(f_or, a, b)
    lines.append(f"gemm_lut_oracle,{us_oracle:.1f},shape={m}x{k}x{n}")
    for name, spec in cases:
        f = jax.jit(lambda x, y, s=spec: G.approx_qgemm(x, y, s))
        us = _time(f, a, b)
        lines.append(
            f"gemm_{name},{us:.1f},planes={spec.rank + 1};"
            f"residual_nmed={spec.residual_nmed:.2e};"
            f"speedup_vs_oracle={us_oracle / us:.1f}x")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
