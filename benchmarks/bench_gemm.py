"""GEMM data-path benchmark: fused vs stacked vs XLA approximate GEMM, plus
the serving weight-plane cache — emits a structured `BENCH_gemm.json` so the
GEMM perf trajectory rides alongside `BENCH_serving.json`.

  PYTHONPATH=src python benchmarks/bench_gemm.py            # full shapes
  PYTHONPATH=src python benchmarks/bench_gemm.py --smoke    # CI
  PYTHONPATH=src python benchmarks/bench_gemm.py --autotune # + tile tuning

`--autotune` tile-tunes the fused path (kernels/autotune.py candidates)
and records this bench's own per-path medians into the tuning cache, so
the `dispatch` decision stamped per mode is the measured argmin and the
check_schema.py `chosen_us <= 1.05x best-of-three` gate is deterministic.
A decode-shaped sweep (m = 1..32) times the skinny-M kernel against the
prefill-shaped fused tile and XLA at every decode batch size.

CPU (interpret-mode) timings are indicative only; the load-bearing numbers
are the STRUCTURAL ones, which hold on any backend:

  * est_hbm_bytes — operand bytes each path materializes through HBM.  The
    stacked path writes+reads `(R+1)x` operand copies (`build_stacks`); the
    fused kernel reads the raw operands once and maps them in-register.
  * builds_stacks — jaxpr inspection: the fused path must contain NO
    (P, M, K)-shaped int8 intermediate for P > 1.
  * weight_cache — per-call µs of the fresh-quantize forward vs the
    prepared-weights forward (the serving engine's decode configuration).
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.approx import gemm as G
from repro.core import multipliers as mm, netlist as nl
from repro.kernels import approx_qgemm as qk
from repro.kernels import autotune, dispatch, ops, ref


def _time(fn, *args, reps: int) -> float:
    """Per-call µs: compile rep, one untimed warm-up rep (first post-compile
    call still pays allocator/first-touch costs), then median of `reps`."""
    jax.block_until_ready(fn(*args))  # compile
    jax.block_until_ready(fn(*args))  # warm-up
    samples = []
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append((time.perf_counter() - t0) * 1e6)
    samples.sort()
    h = len(samples) // 2
    return samples[h] if len(samples) % 2 else \
        0.5 * (samples[h - 1] + samples[h])


def est_hbm_bytes(m: int, k: int, n: int, planes: int, fused: bool) -> int:
    """Operand bytes materialized through HBM for one (m, k, n) GEMM.

    stacked: build_stacks reads the raw operands once and WRITES planes x
    (MK + KN) int8 stacks; the kernel then READS them all back, and writes
    the f32 output.  fused: the kernel reads the raw operands and the
    (R, 256) tables once, and writes the output."""
    operands = m * k + k * n
    out = 4 * m * n
    if fused:
        tables = 2 * 256 * max(planes - 1, 0)
        return operands + tables + out
    return operands + 2 * planes * operands + out


def _jaxpr_builds_stacks(fn, a, b, planes: int) -> bool:
    """Does the traced computation materialize a (P, ~M, ~K) int8 stack?"""
    if planes <= 1:
        return False
    jaxpr = jax.make_jaxpr(fn)(a, b)

    def scan(jx) -> bool:
        for eqn in jx.eqns:
            for v in eqn.outvars:
                aval = getattr(v, "aval", None)
                if aval is None or not hasattr(aval, "shape"):
                    continue
                if (aval.dtype == jnp.int8 and len(aval.shape) == 3
                        and aval.shape[0] == planes):
                    return True
            for sub in eqn.params.values():
                for j in jax.tree_util.tree_leaves(
                        sub, is_leaf=lambda x: hasattr(x, "jaxpr")):
                    if hasattr(j, "jaxpr") and scan(j.jaxpr):
                        return True
        return False

    return scan(jaxpr.jaxpr)


def _tune_fused(spec, m: int, k: int, n: int, reps: int,
                a, b) -> tuple[float, autotune.Candidate]:
    """Time the roofline-pruned fused tile candidates with the bench's own
    timer; (best µs, best candidate)."""
    cands = autotune.candidate_plans(
        m, k, n, spec.n_planes, vmem_budget=dispatch.vmem_budget_bytes())
    if not cands:
        cands = [autotune.Candidate(*qk.choose_blocks(m, k, n))]
    best = None
    for c in cands:
        f = jax.jit(lambda x, y, s=spec, c=c: ops.approx_qgemm(
            x, y, s, bm=None if c.skinny else c.bm, bk=c.bk, bn=c.bn,
            unroll=c.unroll, skinny=c.skinny))
        us = _time(f, a, b, reps=reps)
        if best is None or us < best[0]:
            best = (us, c)
    return best


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=256)
    ap.add_argument("--k", type=int, default=512)
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_gemm.json")
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes / single rep (CI); explicit "
                         "--m/--k/--n/--reps still win")
    ap.add_argument("--autotune", action="store_true",
                    help="tile-tune the fused path per mode and feed this "
                         "bench's own medians into the autotune cache "
                         "($REPRO_TUNING_CACHE), so the recorded dispatch "
                         "decision is the measured argmin")
    args = ap.parse_args(argv)
    if args.smoke:
        defaults = {"m": 256, "k": 512, "n": 256, "reps": 3}
        smoke = {"m": 128, "k": 160, "n": 128, "reps": 1}  # odd K: tail
        for name, val in smoke.items():
            if getattr(args, name) == defaults[name]:
                setattr(args, name, val)

    m, k, n = args.m, args.k, args.n
    rng = np.random.default_rng(args.seed)
    a = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
    b = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
    mask = rng.random(len(nl.bw8().prunable_gates())) < 0.03
    pruned = mm.pruned(mask, name="bench_pruned")

    cases = [
        ("exact", G.from_multiplier(mm.exact_multiplier())),
        ("trunc2x2", G.from_multiplier(mm.truncated(2, 2))),
        ("lowrank_r1", G.from_multiplier(pruned, rank=1)),
        ("lowrank_r2", G.from_multiplier(pruned, rank=2)),
        ("lowrank_r4", G.from_multiplier(pruned, rank=4)),
        ("lowrank_r8", G.from_multiplier(pruned, rank=8)),
    ]

    us_oracle = _time(
        jax.jit(lambda x, y: ref.lut_matmul(x, y, jnp.asarray(pruned.lut))),
        a, b, reps=args.reps)

    default_blocks = dict(zip(("bm", "bk", "bn"), qk.choose_blocks(m, k, n)))
    modes = []
    builds_fused = []
    builds_stacked = []
    for name, spec in cases:
        planes = spec.n_planes
        rank = spec.rank if spec.mode == "lowrank" else 0
        f_fused = jax.jit(lambda x, y, s=spec: ops.approx_qgemm(x, y, s))
        f_stack = jax.jit(
            lambda x, y, s=spec: ops.approx_qgemm(x, y, s, fused=False))
        f_xla = jax.jit(lambda x, y, s=spec: G.approx_qgemm(x, y, s))
        us_fused = _time(f_fused, a, b, reps=args.reps)
        us_stacked = _time(f_stack, a, b, reps=args.reps)
        us_xla = _time(f_xla, a, b, reps=args.reps)
        tuned = None
        if args.autotune:
            us_tuned, cand = _tune_fused(spec, m, k, n, args.reps, a, b)
            us_fused = min(us_fused, us_tuned)
            tuned = {"blocks": {"bm": cand.bm, "bk": cand.bk, "bn": cand.bn,
                                "unroll": cand.unroll,
                                "skinny": cand.skinny},
                     "default_blocks": default_blocks,
                     "us_tuned": us_tuned}
        us = {"fused": us_fused, "stacked": us_stacked, "xla": us_xla}
        if args.autotune:
            # The cache entry's per-path medians ARE this bench's numbers,
            # so the dispatch decision below is the measured argmin by
            # construction (the <= 1.05x best-of-three gate in
            # check_schema.py cannot flake on a noisy runner).
            autotune.record_winner(m, k, n, spec.mode, rank, us,
                                   fused_plan=cand)
        plan = dispatch.choose_gemm_path(spec.policy, m=m, k=k, n=n,
                                         mode=spec.mode, rank=rank,
                                         n_planes=planes)
        bytes_fused = est_hbm_bytes(m, k, n, planes, fused=True)
        bytes_stacked = est_hbm_bytes(m, k, n, planes, fused=False)
        if planes > 1:
            builds_fused.append(_jaxpr_builds_stacks(f_fused, a, b, planes))
            builds_stacked.append(_jaxpr_builds_stacks(f_stack, a, b, planes))
        modes.append({
            "name": name,
            "mode": spec.mode,
            "rank": spec.rank,
            "planes": planes,
            "residual_nmed": float(spec.residual_nmed),
            "us": us,
            "dispatch": plan.as_dict(),
            "chosen_us": us.get(plan.path, us["xla"]),
            "tuned": tuned,
            "est_hbm_bytes": {"fused": bytes_fused, "stacked": bytes_stacked},
            "hbm_reduction": bytes_stacked / bytes_fused,
            "fused_vs_stacked_speedup": us_stacked / max(us_fused, 1e-9),
        })

    # --- weight-plane cache: fresh-quantize vs prepared forward ----------
    spec_wc = G.from_multiplier(pruned, rank=4)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    pw = jax.tree_util.tree_map(
        jax.block_until_ready, G.prepare_weight(w, spec_wc))
    us_fresh = _time(
        jax.jit(lambda xx, ww: G.approx_matmul(xx, ww, spec_wc)),
        x, w, reps=args.reps)
    us_prep = _time(
        jax.jit(lambda xx, ww: G.approx_matmul_prepared(xx, ww, spec_wc)),
        x, pw, reps=args.reps)

    # --- decode-shaped sweep: skinny-M vs prefill-shaped fused vs XLA ----
    spec_dec = G.from_multiplier(pruned, rank=2)
    dec_points = []
    for m_dec in (1, 2, 4, 8, 16, 32):
        a_dec = jnp.asarray(rng.integers(-128, 128, (m_dec, k)), jnp.int8)
        us_skinny = _time(
            jax.jit(lambda x, y, s=spec_dec: ops.approx_qgemm(
                x, y, s, skinny=True)), a_dec, b, reps=args.reps)
        us_padded = _time(
            jax.jit(lambda x, y, s=spec_dec: ops.approx_qgemm(x, y, s)),
            a_dec, b, reps=args.reps)
        us_xla_dec = _time(
            jax.jit(lambda x, y, s=spec_dec: G.approx_qgemm(x, y, s)),
            a_dec, b, reps=args.reps)
        if args.autotune:
            sbk, sbn = qk.choose_skinny_blocks(k, n)
            best_fused = min(us_skinny, us_padded)
            cand_dec = autotune.Candidate(m_dec, sbk, sbn, 1, True) \
                if us_skinny <= us_padded \
                else autotune.Candidate(*qk.choose_blocks(m_dec, k, n))
            autotune.record_winner(
                m_dec, k, n, spec_dec.mode, spec_dec.rank,
                {"fused": best_fused, "xla": us_xla_dec},
                fused_plan=cand_dec)
        dec_points.append({
            "m": m_dec,
            "us": {"skinny": us_skinny, "fused_padded": us_padded,
                   "xla": us_xla_dec},
            "skinny_speedup_vs_fused": us_padded / max(us_skinny, 1e-9),
        })

    tuning_cache = autotune.load_cache()
    report = {
        "bench": "gemm",
        "smoke": args.smoke,
        "backend": jax.default_backend(),
        "shape": {"m": m, "k": k, "n": n},
        "reps": args.reps,
        "lut_oracle_us": us_oracle,
        "modes": modes,
        "decode_sweep": {
            "mult": spec_dec.name,
            "mode": spec_dec.mode,
            "rank": spec_dec.rank,
            "k": k,
            "n": n,
            "points": dec_points,
        },
        "tuning": {
            "autotuned": args.autotune,
            "cache_path": autotune.cache_path(),
            "kernel_version": qk.KERNEL_VERSION,
            "entries": len(tuning_cache.get("entries", {})),
        },
        "structural": {
            "fused_builds_stacks": any(builds_fused),
            "stacked_builds_stacks": all(builds_stacked),
        },
        "weight_cache": {
            "mult": spec_wc.name,
            "rank": spec_wc.rank,
            "us_fresh": us_fresh,
            "us_prepared": us_prep,
            "hit_speedup": us_fresh / max(us_prep, 1e-9),
        },
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    for mo in modes:
        print(f"[bench_gemm] {mo['name']:<11} planes={mo['planes']} "
              f"fused {mo['us']['fused']:9.1f}us  "
              f"stacked {mo['us']['stacked']:9.1f}us  "
              f"xla {mo['us']['xla']:9.1f}us  "
              f"-> {mo['dispatch']['path']} ({mo['dispatch']['source']})  "
              f"hbm x{mo['hbm_reduction']:.2f} less")
    for pt in dec_points:
        print(f"[bench_gemm] decode m={pt['m']:<3} "
              f"skinny {pt['us']['skinny']:9.1f}us  "
              f"padded-fused {pt['us']['fused_padded']:9.1f}us  "
              f"xla {pt['us']['xla']:9.1f}us  "
              f"(skinny x{pt['skinny_speedup_vs_fused']:.2f})")
    wc = report["weight_cache"]
    print(f"[bench_gemm] weight-cache ({wc['mult']} r{wc['rank']}): "
          f"fresh {wc['us_fresh']:.1f}us -> prepared {wc['us_prepared']:.1f}us "
          f"({wc['hit_speedup']:.2f}x) -> {args.out}")
    return report


def csv_main() -> list[str]:
    """benchmarks/run.py entry: smoke shapes to a temp file (the cwd
    BENCH_gemm.json artifact is the CLI's, not the suite's), report as
    CSV lines."""
    import os
    import tempfile
    fd, path = tempfile.mkstemp(suffix=".json")
    os.close(fd)
    try:
        r = main(["--smoke", "--out", path])
    finally:
        os.unlink(path)
    lines = []
    for mo in r["modes"]:
        lines.append(
            f"gemm_{mo['name']}_fused,{mo['us']['fused']:.1f},"
            f"planes={mo['planes']};hbm_reduction={mo['hbm_reduction']:.2f}")
        lines.append(f"gemm_{mo['name']}_stacked,{mo['us']['stacked']:.1f},"
                     f"planes={mo['planes']}")
    wc = r["weight_cache"]
    lines.append(f"gemm_weight_cache_prepared,{wc['us_prepared']:.1f},"
                 f"hit_speedup={wc['hit_speedup']:.2f}x")
    return lines


if __name__ == "__main__":
    main()
