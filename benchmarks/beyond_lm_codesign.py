"""Beyond-paper: the paper's carbon-aware co-design applied to a
transformer (LM) edge workload instead of CNNs.

The dataflow model maps GEMM layers onto the same NVDLA-style loop nest
(core/workloads.py::transformer_block_gemms), so the identical
GA-CDP machinery sizes an edge accelerator for token generation under a
sequences/second constraint.  This is the bridge between the paper's
methodology and the 10 assigned LM architectures: the same co-design loop,
with the JAX framework supplying the accuracy constraint at LM scale."""

from __future__ import annotations

import time

from repro.core import codesign, ga, multipliers as mm, pareto


def rows() -> list[dict]:
    mults = pareto.default_front() + list(mm.static_library().values())
    out = []
    for node in (7, 14, 28):
        # "fps" = sequences (128 tokens) per second for the tiny LM
        rep = codesign.run_codesign(
            "tiny_lm", node, fps_min=50.0, max_accuracy_drop=2.0,
            mults=mults,
            ga_cfg=ga.GAConfig(pop_size=20, generations=10, seed=0))
        out.append({
            "workload": "tiny_lm", "node_nm": node,
            "exact_carbon_g": round(rep.exact.carbon_g, 2),
            "ga_carbon_g": round(rep.ga_cdp.carbon_g, 2),
            "saving_pct": round(100 * rep.ga_reduction, 2),
            "ga_pes": rep.ga_cdp.config.num_pes,
            "ga_mult": rep.ga_cdp.config.multiplier,
            "ga_seq_per_s": round(rep.ga_cdp.fps, 1),
        })
    return out


def main() -> list[str]:
    t0 = time.time()
    rs = rows()
    us = (time.time() - t0) * 1e6 / max(len(rs), 1)
    return [
        "beyond_lm_codesign,{:.1f},{}".format(
            us, ";".join(f"{k}={v}" for k, v in r.items()))
        for r in rs
    ]


if __name__ == "__main__":
    print("\n".join(main()))
