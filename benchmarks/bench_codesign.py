"""Co-design engine benchmark: population-parallel (JAX-batched) GA vs the
sequential numpy reference, plus scenario sweeps with serving-calibrated
delay — emits a structured `BENCH_codesign.json` so the search itself
rides the bench trajectory alongside `BENCH_gemm.json` /
`BENCH_serving.json`.

  PYTHONPATH=src python benchmarks/bench_codesign.py            # full grid
  PYTHONPATH=src python benchmarks/bench_codesign.py --smoke    # CI

Sections of the report:

  * parity    — the batched engine and the numpy twin must select the SAME
                best-CDP design at fixed seeds (per workload).
  * population_eval — wall time to evaluate one `--pop`-genome population
                through each engine (steady state: jit compiled, caches
                warm).  The acceptance bar is a >=10x batched speedup at
                4096 genomes.
  * ga        — end-to-end batched GA wall time at that population size.
  * calibration — measured-vs-analytical throughput anchor
                (`core/calibrate.py`): serving engine trace or fused-GEMM
                kernel timing.
  * scenarios — (node x fab carbon intensity x workload) sweep, each point
                solved by the batched GA, with analytical and calibrated
                CDP, plus the (carbon, delay) frontier of the final GA
                population.
  * total_carbon — the fleet loop closed into co-design: CDP winner vs
                the amortized-embodied + operational winner under an
                `repro.fleet.total.OperationalModel`, per scenario, with
                at least one point where pricing operational carbon
                changes the chosen design.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import calibrate as calmod
from repro.core import carbon as carbonmod
from repro.core import codesign
from repro.core import ga
from repro.core import ga_batched as gb
from repro.core import multipliers as mm


def _parity_mults() -> list[mm.ApproxMultiplier]:
    return [mm.exact_multiplier(), mm.truncated(1, 1), mm.truncated(2, 2),
            mm.truncated(3, 3)]


def parity_check(workloads: list[str], node_nm: int, seed: int) -> list[dict]:
    out = []
    for wk in workloads:
        mults = _parity_mults()
        rb = gb.run_ga_batched(
            wk, node_nm, 30.0, 2.0, mults=mults,
            cfg=gb.BatchedGAConfig(pop_size=2048, generations=8, seed=seed))
        rn = ga.run_ga(wk, node_nm, 30.0, 2.0, mults=mults,
                       cfg=ga.GAConfig(pop_size=32, generations=16,
                                       seed=seed))
        out.append({
            "workload": wk, "node_nm": node_nm, "seed": seed,
            # the config dataclass does not carry the die gene — compare
            # it explicitly or a (X, 4-die) vs (X, 1-die) split would
            # still read as a MATCH
            "match": (rb.best.config == rn.best.config
                      and rb.best.n_dies == rn.best.n_dies),
            "batched": {"config": str(rb.best.config),
                        "n_dies": rb.best.n_dies, "cdp": rb.best.cdp,
                        "fitness": rb.best.fitness},
            "numpy": {"config": str(rn.best.config),
                      "n_dies": rn.best.n_dies, "cdp": rn.best.cdp,
                      "fitness": rn.best.fitness},
        })
    return out


def population_eval_timing(workload: str, node_nm: int, pop_size: int,
                           seed: int, reps: int) -> dict:
    """Steady-state wall time for one whole-population CDP evaluation."""
    mults = _parity_mults()
    space = gb.build_space(workload, node_nm, 30.0, 2.0, mults=mults)
    rng = np.random.default_rng(seed)
    pop = np.stack([rng.integers(0, n, pop_size)
                    for n in space.gene_sizes], axis=1).astype(np.int32)
    # mask the mult and die genes to the feasible set (what the GA
    # guarantees): infeasible genomes score +inf on both engines, which
    # would turn the relative-error check into inf - inf
    allowed_idx = np.flatnonzero(space.mult_allowed)
    pop[:, gb.MULT_GENE] = allowed_idx[pop[:, gb.MULT_GENE]
                                       % len(allowed_idx)]
    die_ok = space.die_ok[pop[:, 0], pop[:, 1], pop[:, gb.DIE_GENE]]
    pop[:, gb.DIE_GENE] = np.where(die_ok, pop[:, gb.DIE_GENE], 0)

    # numpy reference: warm the workload_perf lru cache, then time
    gcfg = ga.GAConfig()
    def numpy_pass():
        return [ga.evaluate(space.decode(row), workload, node_nm,
                            list(space.mults), 30.0, gcfg) for row in pop]
    numpy_pass()
    t0 = time.perf_counter()
    for _ in range(reps):
        evs = numpy_pass()
    numpy_s = (time.perf_counter() - t0) / reps

    # batched engine: compile, then time
    tables = space.tables()
    jpop = jnp.asarray(pop)
    met = jax.block_until_ready(
        gb.evaluate_population(jpop, tables, node_nm))
    t0 = time.perf_counter()
    for _ in range(reps):
        met = jax.block_until_ready(
            gb.evaluate_population(jpop, tables, node_nm))
    batched_s = (time.perf_counter() - t0) / reps

    # the two evaluators must agree on every genome, not just the argmin
    fit_np = np.array([e.fitness for e in evs])
    rel = np.abs(np.asarray(met["fitness"]) - fit_np) / np.abs(fit_np)
    return {
        "workload": workload, "node_nm": node_nm, "pop_size": pop_size,
        "reps": reps,
        "numpy_s": numpy_s, "batched_s": batched_s,
        "speedup": numpy_s / max(batched_s, 1e-12),
        "max_rel_fitness_err": float(rel.max()),
    }


def ga_timing(workload: str, node_nm: int, pop_size: int, generations: int,
              seed: int) -> dict:
    mults = _parity_mults()
    cfg = gb.BatchedGAConfig(pop_size=pop_size, generations=generations,
                             seed=seed)
    t0 = time.perf_counter()
    res = gb.run_ga_batched(workload, node_nm, 30.0, 2.0, mults=mults,
                            cfg=cfg)
    wall = time.perf_counter() - t0
    return {"workload": workload, "pop_size": pop_size,
            "generations": generations, "wall_s": wall,
            "best_cdp": res.best.cdp,
            "best_config": str(res.best.config),
            "history": res.history}


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pop", type=int, default=4096)
    ap.add_argument("--generations", type=int, default=12)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--node", type=int, default=7, choices=(7, 14, 28))
    ap.add_argument("--calibration", default="",
                    choices=["", "none", "serving", "gemm"],
                    help="delay anchor (default: serving; smoke: serving)")
    ap.add_argument("--calibration-mesh", default="",
                    help="serve the calibration trace tensor-parallel, "
                         "e.g. 'model=4' (serving source only; needs that "
                         "many devices)")
    ap.add_argument("--out", default="BENCH_codesign.json")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced scenario grid + small GA (CI); the "
                         "4096-genome population timing is kept as-is")
    args = ap.parse_args(argv)

    parity_workloads = ["vgg16", "resnet50"]
    if args.smoke:
        scen = codesign.scenario_grid(
            workloads=("vgg16", "lm_serving"), nodes=(7, 28),
            ci_fabs=(50.0, carbonmod.CI_FAB_G_PER_KWH))
        ga_gens = min(args.generations, 6)
    else:
        scen = codesign.scenario_grid()
        ga_gens = args.generations
    # multi-die pressure points: FPS floors above monolithic (one DRAM
    # channel) reach — where the GA must trade die partitioning against
    # packaging carbon and D2D delay
    scen += codesign.multi_die_scenarios()

    parity = parity_check(parity_workloads, args.node, args.seed)
    pop_eval = population_eval_timing("vgg16", args.node, args.pop,
                                      args.seed, args.reps)
    ga_wall = ga_timing("vgg16", args.node, args.pop, ga_gens, args.seed)

    cal_kwargs = {}
    if args.calibration_mesh and (args.calibration or "serving") == \
            "serving":
        cal_kwargs["mesh_spec"] = args.calibration_mesh
    calib = calmod.get_calibration(args.calibration or "serving",
                                   node_nm=args.node, **cal_kwargs)
    results = codesign.run_scenarios(
        scen, mults=_parity_mults(),
        cfg=gb.BatchedGAConfig(pop_size=512 if args.smoke else args.pop,
                               generations=ga_gens, seed=args.seed),
        calibration=calib)

    scenario_dicts = [r.to_dict() for r in results]
    # multi-die wins: scenarios where the GA selected >1 die AND beat the
    # best monolithic design on the constrained-CDP fitness
    multi_wins = [
        {"scenario": s["scenario"], "n_dies": s["best"]["n_dies"],
         "cdp_constrained": s["best"]["cdp_constrained"],
         "mono_cdp_constrained": s["best_monolithic"]["cdp_constrained"],
         "die_yield": s["best"]["die_yield"],
         "packaging_g": s["best"]["packaging_g"]}
        for s in scenario_dicts
        if s["best"]["n_dies"] > 1 and s["best_monolithic"] is not None
        and s["best"]["cdp_constrained"] <
        s["best_monolithic"]["cdp_constrained"]]

    # total-carbon axis: same pressure-point scenarios, winners compared
    # under a deployment's operational model (grid CI, lifetime, D2D
    # link power) — ground-truth exhaustive search, cheap at this space
    from repro.fleet.total import OperationalModel
    total_carbon = codesign.run_total_carbon(
        codesign.multi_die_scenarios(), OperationalModel(),
        mults=_parity_mults())

    report = {
        "bench": "codesign",
        "smoke": args.smoke,
        "backend": jax.default_backend(),
        "seed": args.seed,
        "parity": parity,
        "population_eval": pop_eval,
        "ga": ga_wall,
        "calibration": calib.to_dict(),
        "scenarios": scenario_dicts,
        "multi_die_wins": multi_wins,
        "total_carbon": total_carbon,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)

    for p in parity:
        print(f"[bench_codesign] parity {p['workload']}: "
              f"{'MATCH' if p['match'] else 'MISMATCH'} "
              f"(cdp {p['batched']['cdp']:.4g})")
    print(f"[bench_codesign] population eval P={pop_eval['pop_size']}: "
          f"numpy {pop_eval['numpy_s'] * 1e3:.1f}ms -> batched "
          f"{pop_eval['batched_s'] * 1e3:.2f}ms "
          f"({pop_eval['speedup']:.1f}x)")
    print(f"[bench_codesign] calibration ({calib.source}): scale "
          f"{calib.scale:.3e} ({calib.measured:.3g} measured vs "
          f"{calib.analytical:.3g} analytical {calib.unit})")
    for r in results:
        cal = (f" cdp_cal {r.cdp_calibrated:.3g}"
               if r.cdp_calibrated is not None else "")
        dies = f" x{r.best.n_dies}die" if r.best.n_dies > 1 else ""
        print(f"[bench_codesign] {r.scenario.name}: "
              f"{r.best.config.num_pes} PEs{dies} "
              f"mult={r.best.config.multiplier} "
              f"carbon {-100 * r.ga_reduction:+.1f}% "
              f"cdp {r.best.cdp:.3g}{cal} ({r.wall_s:.1f}s)")
    for w in multi_wins:
        sc = w["scenario"]
        print(f"[bench_codesign] multi-die win: {sc['workload']}@"
              f"{sc['node_nm']}nm fps>={sc['fps_min']:.0f}: "
              f"{w['n_dies']} dies (yield {w['die_yield']:.3f}, "
              f"pkg {w['packaging_g']:.1f} g) cdp* "
              f"{w['cdp_constrained']:.3g} vs mono "
              f"{w['mono_cdp_constrained']:.3g}")
    for s in total_carbon:
        sc = s["scenario"]
        tag = "DIFFERS" if s["differs"] else "same"
        print(f"[bench_codesign] total-carbon {sc['workload']}@"
              f"{sc['node_nm']}nm fps>={sc['fps_min']:.0f} "
              f"ci_use={s['op']['ci_use_g_per_kwh']:.0f}: {tag}; "
              f"total {s['total_winner']['total_g_per_inf']:.3e} vs "
              f"cdp-design {s['cdp_winner']['total_g_per_inf']:.3e} g/inf "
              f"({100 * s['total_reduction']:+.2f}%)")
    print(f"[bench_codesign] -> {args.out}")
    return report


def csv_main() -> list[str]:
    """benchmarks/run.py entry: smoke run to a temp file, report as CSV."""
    import os
    import tempfile
    fd, path = tempfile.mkstemp(suffix=".json")
    os.close(fd)
    try:
        r = main(["--smoke", "--calibration", "gemm", "--out", path])
    finally:
        os.unlink(path)
    pe = r["population_eval"]
    lines = [
        f"codesign_pop_eval_numpy,{pe['numpy_s'] * 1e6:.0f},"
        f"pop={pe['pop_size']}",
        f"codesign_pop_eval_batched,{pe['batched_s'] * 1e6:.0f},"
        f"speedup={pe['speedup']:.1f}x",
        f"codesign_ga_batched,{r['ga']['wall_s'] * 1e6:.0f},"
        f"pop={r['ga']['pop_size']};gens={r['ga']['generations']}",
    ]
    for s in r["scenarios"]:
        sc = s["scenario"]
        lines.append(
            f"codesign_{sc['workload']}_{sc['node_nm']}nm_"
            f"ci{sc['ci_fab_g_per_kwh']:.0f},{s['wall_s'] * 1e6:.0f},"
            f"reduction={100 * s['ga_reduction']:.1f}%")
    return lines


if __name__ == "__main__":
    main()
