"""Pallas kernel microbenchmarks (interpret mode on CPU: correctness-scale
timings; the BlockSpec/VMEM structure is the TPU artifact)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.approx import gemm as G
from repro.core import multipliers as mm
from repro.kernels import ops


def _time(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def main() -> list[str]:
    rng = np.random.default_rng(0)
    lines = []

    a = jnp.asarray(rng.integers(-128, 128, (256, 512)), jnp.int8)
    b = jnp.asarray(rng.integers(-128, 128, (512, 256)), jnp.int8)
    for name in ("exact", "trunc2x2"):
        spec = G.spec_from_name(name)
        us = _time(lambda x, y, s=spec: ops.approx_qgemm(x, y, s), a, b)
        flops = 2 * 256 * 512 * 256 * (spec.rank + 1)
        lines.append(f"kernel_qgemm_{name},{us:.1f},"
                     f"gflops_equiv={flops / us / 1e3:.2f}")

    q = jnp.asarray(rng.standard_normal((4, 512, 64)), jnp.float32)
    us = _time(lambda x: ops.flash_attention(x, x, x, causal=True,
                                             bq=128, bkv=128), q)
    lines.append(f"kernel_flash_attention,{us:.1f},bh=4;s=512;d=64")

    x = jnp.asarray(rng.standard_normal((512, 1024)), jnp.float32)
    us = _time(lambda v: ops.quantize_rows(v), x)
    lines.append(f"kernel_quantize_rows,{us:.1f},m=512;k=1024")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
