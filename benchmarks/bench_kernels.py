"""Pallas kernel microbenchmarks (interpret mode on CPU: correctness-scale
timings; the BlockSpec/VMEM structure is the TPU artifact).

Each GEMM cell runs the full float-in/float-out `approx_matmul` path twice —
once per kernel-dispatch policy ("pallas" vs "xla", kernels/dispatch.py) —
so the benchmark exercises exactly the dispatch models/serving use, plus
the direct int8 kernel for the raw MXU-path number.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.approx import gemm as G
from repro.core import multipliers as mm
from repro.kernels import dispatch, ops


def _time(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def main() -> list[str]:
    rng = np.random.default_rng(0)
    lines = []
    lines.append(f"kernel_dispatch_info,0.0,"
                 f"interpret={dispatch.interpret_mode()};"
                 f"default_policy={dispatch.default_policy()}")

    a = jnp.asarray(rng.integers(-128, 128, (256, 512)), jnp.int8)
    b = jnp.asarray(rng.integers(-128, 128, (512, 256)), jnp.int8)
    x = jnp.asarray(rng.standard_normal((256, 512)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((512, 256)), jnp.float32)
    for name in ("exact", "trunc2x2"):
        spec = G.spec_from_name(name)
        us = _time(lambda p, q, s=spec: ops.approx_qgemm(p, q, s), a, b)
        flops = 2 * 256 * 512 * 256 * spec.n_planes
        lines.append(f"kernel_qgemm_{name},{us:.1f},"
                     f"gflops_equiv={flops / us / 1e3:.2f}")
        # end-to-end dispatch path (quantize + GEMM + dequant) per policy
        for policy in ("pallas", "xla"):
            sp = spec.with_policy(policy)
            us = _time(lambda p, q, s=sp: G.approx_matmul(p, q, s), x, w)
            lines.append(f"approx_matmul_{name}_{policy},{us:.1f},"
                         f"m=256;k=512;n=256")

    q = jnp.asarray(rng.standard_normal((4, 512, 64)), jnp.float32)
    us = _time(lambda t: ops.flash_attention(t, t, t, causal=True,
                                             bq=128, bkv=128), q)
    lines.append(f"kernel_flash_attention,{us:.1f},bh=4;s=512;d=64")

    xq = jnp.asarray(rng.standard_normal((512, 1024)), jnp.float32)
    us = _time(lambda v: ops.quantize_rows(v), xq)
    lines.append(f"kernel_quantize_rows,{us:.1f},m=512;k=1024")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
