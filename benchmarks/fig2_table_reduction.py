"""Paper Fig. 2 embedded table: approx-only carbon-footprint reduction (%)
— average and peak over the 64..2048-PE sweep — per technology node
(7/14/28 nm) x accuracy-drop budget (0.5/1.0/2.0 %).

Paper's claimed bands: avg 2.83-8.44 %, peak 4.60-12.75 %.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import codesign, multipliers as mm, pareto

PAPER = {  # (node, drop) -> (avg, peak) from the paper's table
    (7, 0.5): (2.83, 5.78), (7, 1.0): (4.49, 9.18), (7, 2.0): (5.17, 10.56),
    (14, 0.5): (5.58, 8.87), (14, 1.0): (6.90, 10.98),
    (14, 2.0): (8.02, 12.75),
    (28, 0.5): (3.33, 4.60), (28, 1.0): (5.71, 7.87), (28, 2.0): (8.44, 11.65),
}


def rows() -> list[dict]:
    mults = pareto.default_front() + list(mm.static_library().values())
    out = []
    for node in (7, 14, 28):
        exact = codesign.sweep_exact_configs("vgg16", node)
        for drop in (0.5, 1.0, 2.0):
            appx = codesign.approx_only_sweep("vgg16", node, drop, mults)
            reds = [100.0 * (1 - a.carbon_g / e.carbon_g)
                    for a, e in zip(appx, exact)]
            pa, pp = PAPER[(node, drop)]
            out.append({
                "node_nm": node, "drop_pct": drop,
                "avg_reduction_pct": round(float(np.mean(reds)), 2),
                "peak_reduction_pct": round(float(np.max(reds)), 2),
                "paper_avg": pa, "paper_peak": pp,
            })
    return out


def main() -> list[str]:
    t0 = time.time()
    rs = rows()
    us = (time.time() - t0) * 1e6 / max(len(rs), 1)
    return [
        "fig2_table_reduction,{:.1f},{}".format(
            us, ";".join(f"{k}={v}" for k, v in r.items()))
        for r in rs
    ]


if __name__ == "__main__":
    print("\n".join(main()))
