"""Paper Fig. 2: embodied carbon vs performance for VGG16 at 7 nm.

Reproduces the three curve families:
  * exact baseline accelerators (64..2048 PEs, NVDLA scaling),
  * approx-only variants (same architecture, Pareto multiplier within
    0.5 / 1.0 / 2.0 % accuracy-drop budgets),
  * GA-CDP designs at 30 / 40 / 50 FPS thresholds.
"""

from __future__ import annotations

import time

from repro.core import codesign, ga, multipliers as mm, pareto


def rows() -> list[dict]:
    out = []
    mults = pareto.default_front() + list(mm.static_library().values())
    for e in codesign.sweep_exact_configs("vgg16", 7):
        out.append({"series": "exact", "pes": e.config.num_pes,
                    "fps": round(e.fps, 2), "carbon_g": round(e.carbon_g, 3),
                    "mult": "exact"})
    for drop in (0.5, 1.0, 2.0):
        sweep = codesign.approx_only_sweep("vgg16", 7, drop, mults)
        exact = codesign.sweep_exact_configs("vgg16", 7)
        for e, x in zip(sweep, exact):
            out.append({"series": f"appx_{drop}", "pes": e.config.num_pes,
                        "fps": round(x.fps, 2),
                        "carbon_g": round(e.carbon_g, 3),
                        "mult": e.config.multiplier})
    for fps_min in (30.0, 40.0, 50.0):
        rep = codesign.run_codesign(
            "vgg16", 7, fps_min, 2.0, mults=mults,
            ga_cfg=ga.GAConfig(pop_size=24, generations=12, seed=0))
        out.append({"series": f"ga_cdp_{fps_min:.0f}fps",
                    "pes": rep.ga_cdp.config.num_pes,
                    "fps": round(rep.ga_cdp.fps, 2),
                    "carbon_g": round(rep.ga_cdp.carbon_g, 3),
                    "mult": rep.ga_cdp.config.multiplier,
                    "reduction_vs_exact_pct":
                        round(100 * rep.ga_reduction, 2)})
    return out


def main() -> list[str]:
    t0 = time.time()
    rs = rows()
    us = (time.time() - t0) * 1e6 / max(len(rs), 1)
    lines = []
    for r in rs:
        derived = ";".join(f"{k}={v}" for k, v in r.items())
        lines.append(f"fig2_vgg16_tradeoff,{us:.1f},{derived}")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
