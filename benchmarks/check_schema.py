"""Schema/invariant checks for the BENCH_*.json bench reports.

One place for the assertions that used to live as three copies of
inline ``python - <<EOF`` heredocs in .github/workflows/ci.yml — now
shared by CI, `tests/test_analysis.py` (which validates the checked-in
reports), and anyone running a bench locally:

    python benchmarks/check_schema.py BENCH_serving.json
    python benchmarks/check_schema.py BENCH_serving.json \
        --expect-mesh data=4,model=2
    python benchmarks/check_schema.py BENCH_gemm.json BENCH_codesign.json

The report kind is read from the file's "bench" field.  Each check
raises AssertionError with the offending fragment; the CLI exits 1.
"""

from __future__ import annotations

import argparse
import json
import sys


def check_serving(r: dict, expect_mesh: dict | None = None,
                  expect_carbon: bool = False,
                  expect_paged: bool = False) -> None:
    assert r["bench"] == "serving", r.get("bench")
    assert r["engine"]["completed"] == r["trace"]["requests"], r
    # per-request TTFT percentiles + queue-wait/eviction accounting
    m = r["metrics"]
    assert {"ttft_p50_s", "ttft_p95_s", "ttft_mean_s",
            "latency_p50_s", "latency_p95_s"} <= set(m), m
    assert 0 < m["ttft_p50_s"] <= m["ttft_p95_s"], m
    e = r["engine"]
    assert {"queue_wait_ticks_total", "queue_wait_ticks_mean",
            "evictions", "mesh"} <= set(e), e
    assert sum(e["evictions"].values()) == e["completed"], e
    assert r["mesh"] == e["mesh"], r["mesh"]
    if expect_mesh is not None:
        assert r["mesh"] == expect_mesh, (r["mesh"], expect_mesh)
    if "retrace" in r:  # bench ran with --sanitize-retrace
        assert r["retrace"]["ok"] is True, r["retrace"]["findings"]
        w = r["retrace"]["watches"]
        # a tier-ladder engine suffixes the decode watch per tier
        # (serving/engine:decode[exact], ...): each compiles exactly once
        dec = [k for k in w if k.startswith("serving/engine:decode")]
        assert dec, w
        for k in dec:
            assert w[k]["compiles"] == 1, (k, w[k])
    if expect_carbon or "carbon" in r:  # bench ran with --meter
        assert {"energy_j", "co2e_g", "co2e_g_per_token",
                "energy_j_per_token"} <= set(m), m
        assert m["energy_j"] > 0 and m["co2e_g"] > 0, m
        assert m["co2e_g_per_token"] > 0, m
        c = r["carbon"]
        assert c["g_per_kwh"] > 0 and c["region"], c
        # per-token figure must be consistent with the totals
        tol = 1e-9 + 1e-6 * m["co2e_g"]
        assert abs(m["co2e_g_per_token"] * m["total_tokens"]
                   - m["co2e_g"]) <= tol, m
    if expect_paged or "paged" in r:  # bench ran the paged comparison
        assert "paged" in r, "serving report has no 'paged' section"
        p = r["paged"]
        assert p["page_size"] > 0 and p["traces"], p
        assert p["paged_capacity"] > p["slot_capacity"], p
        assert p["kv_pool_tokens"] == (p["slot_capacity"]
                                       * r["trace"]["max_len"]), p
        for name, t in p["traces"].items():
            # the differential invariant rides in the bench: paged +
            # chunked + speculative emits EXACTLY the slot engine's
            # token streams on every trace
            assert t["tokens_match"] is True, (name, t)
            for kind in ("slot", "paged"):
                row = t[kind]
                assert {"wall_s", "ttft_p50_s", "ttft_p95_s",
                        "ttft_p50_ticks", "ttft_p95_ticks",
                        "latency_p95_s",
                        "decode_tokens_per_s"} <= set(row), t
                assert row["ttft_p95_ticks"] >= 1, t
            a = t["alloc"]
            assert a["alloc_failures"] == 0, (name, a)
            if name == "shared-prefix":
                assert a["prefix_hit_tokens"] > 0, (name, a)
            if name in ("long-prompt", "burst"):
                # equal-KV-memory page admission + speculation: p95
                # ticks-to-first-token at least halves vs whole-slot
                assert t["ttft_p95_ticks_improvement"] >= 2.0, (name, t)
            for k in ("slot_retrace_ok", "paged_retrace_ok"):
                if k in t:
                    assert t[k] is True, (name, t)
        if p.get("draft_tier"):
            s = r["spec"]
            assert s["proposed"] > 0, s
            assert 0 <= s["accepted"] <= s["proposed"], s
            assert 0.0 < s["acceptance_rate"] <= 1.0, s
            if p["draft_tier"] == "exact":
                assert s["acceptance_rate"] == 1.0, s


def check_gemm(r: dict, expect_tuning: bool = False) -> None:
    assert r["bench"] == "gemm" and r["modes"], r
    tuned_run = bool(r.get("tuning", {}).get("autotuned"))
    if expect_tuning:
        assert tuned_run, "gemm report was not produced with --autotune"
    for m in r["modes"]:
        assert {"name", "mode", "rank", "planes", "us", "dispatch",
                "chosen_us", "est_hbm_bytes", "hbm_reduction",
                "fused_vs_stacked_speedup"} <= set(m), m
        assert {"fused", "stacked", "xla"} <= set(m["us"]), m
        d = m["dispatch"]
        assert d["path"] in ("fused", "stacked", "xla"), m
        assert d["source"] in ("policy", "tuned", "roofline", "default"), m
        assert m["chosen_us"] == m["us"][d["path"]], m
        if tuned_run:
            # the auto-dispatch regression gate: the chosen path may never
            # lose the three-way race by more than measurement slack.  The
            # bench feeds its own medians into the tuning cache before
            # asking dispatch, so this holds by construction when healthy
            # and only fails on a real dispatch/cache bug.
            assert d["source"] == "tuned", m
            best = min(m["us"].values())
            assert m["chosen_us"] <= 1.05 * best, (m["name"], m["us"], d)
            assert m.get("tuned"), m
            assert {"blocks", "default_blocks",
                    "us_tuned"} <= set(m["tuned"]), m
    # decode-shaped sweep: the skinny-M kernel must beat the prefill-
    # shaped (m-padded) fused tile at small decode batches
    ds = r["decode_sweep"]
    assert ds["points"], ds
    ms = [p["m"] for p in ds["points"]]
    assert ms == sorted(ms) and ms[0] <= 8, ms
    for p in ds["points"]:
        assert {"skinny", "fused_padded", "xla"} <= set(p["us"]), p
        if p["m"] <= 8:
            assert p["us"]["skinny"] < p["us"]["fused_padded"], p
    t = r["tuning"]
    assert {"autotuned", "cache_path", "kernel_version",
            "entries"} <= set(t), t
    if tuned_run:
        assert t["entries"] >= len(r["modes"]), t
    # the load-bearing fused-beats-stacked check is structural:
    # the fused jaxpr must not materialize operand stacks at all
    s = r["structural"]
    assert s["fused_builds_stacks"] is False, s
    assert s["stacked_builds_stacks"] is True, s
    # weight-cache timings ride in the artifact for the perf trajectory
    # (too noisy on CI runners to gate on a threshold); schema only:
    assert {"mult", "rank", "us_fresh", "us_prepared",
            "hit_speedup"} <= set(r["weight_cache"]), r


def check_codesign(r: dict) -> None:
    assert r["bench"] == "codesign", r.get("bench")
    # parity: the batched engine and the numpy reference twin must
    # select the SAME best-CDP design (deterministic at fixed seed)
    assert len(r["parity"]) >= 2, r["parity"]
    for p in r["parity"]:
        assert {"workload", "match", "batched", "numpy"} <= set(p), p
        assert p["match"] is True, p
    # population-eval timing: both engines' numbers recorded; the
    # batched engine must win (the >=10x figure is recorded for the
    # perf trajectory; CI gates only on a noise-safe floor)
    pe = r["population_eval"]
    assert {"pop_size", "numpy_s", "batched_s", "speedup",
            "max_rel_fitness_err"} <= set(pe), pe
    assert pe["pop_size"] >= 4096 and pe["speedup"] > 1.0, pe
    assert pe["max_rel_fitness_err"] < 1e-4, pe
    assert {"wall_s", "best_cdp", "history"} <= set(r["ga"]), r["ga"]
    # calibration: measured + analytical throughput and the scale
    c = r["calibration"]
    assert {"measured", "analytical", "scale", "source",
            "unit"} <= set(c), c
    assert c["measured"] > 0 and c["scale"] > 0, c
    # scenario sweep covers >1 node and >1 fab carbon intensity
    assert len(r["scenarios"]) >= 4, len(r["scenarios"])
    for s in r["scenarios"]:
        assert {"scenario", "best", "best_monolithic",
                "exact_baseline", "ga_reduction",
                "cdp_calibrated", "wall_s"} <= set(s), s
        assert s["best"]["carbon_g"] > 0 and s["best"]["fps"] > 0, s
        # multi-die reporting: per-die yield + packaging recorded
        assert {"n_dies", "die_area_mm2", "die_yield",
                "packaging_g", "cdp_constrained"} <= set(s["best"]), s
    nodes = {s["scenario"]["node_nm"] for s in r["scenarios"]}
    cis = {s["scenario"]["ci_fab_g_per_kwh"] for s in r["scenarios"]}
    assert len(nodes) >= 2 and len(cis) >= 2, (nodes, cis)
    # carbon/delay frontier of the final GA population per scenario:
    # nondominated and sorted by carbon
    for s in r["scenarios"]:
        fr = s["frontier"]
        assert fr, s["scenario"]
        carbons = [p["carbon_g"] for p in fr]
        delays = [p["delay_s"] for p in fr]
        assert carbons == sorted(carbons), fr
        assert all(c > 0 and d > 0 for c, d in zip(carbons, delays)), fr
        # sorted by carbon ascending => delay must descend (no point may
        # dominate another)
        assert all(delays[i] >= delays[i + 1]
                   for i in range(len(fr) - 1)), fr
    # multi-die co-design is live: at least one scenario where the
    # GA selects >1 die AND beats the best monolithic design on the
    # constrained-CDP fitness, with yield/packaging recorded
    assert len(r["multi_die_wins"]) >= 1, r["multi_die_wins"]
    for w in r["multi_die_wins"]:
        assert w["n_dies"] > 1 and 0 < w["die_yield"] <= 1, w
        assert w["packaging_g"] > 0, w
        assert w["cdp_constrained"] < w["mono_cdp_constrained"], w
    # total-carbon axis: embodied + operational per inference, and at
    # least one scenario where pricing operational carbon changes the
    # winning design vs pure CDP
    tc = r["total_carbon"]
    assert len(tc) >= 2, tc
    for s in tc:
        for k in ("cdp_winner", "total_winner"):
            d = s[k]
            assert d["total_g_per_inf"] > 0, s
            assert d["operational_g_per_inf"] >= 0, s
            assert d["embodied_g_per_inf"] > 0, s
            lo = d["total_g_per_inf"] * (1 - 1e-6)
            hi = d["total_g_per_inf"] * (1 + 1e-6)
            assert (lo <= d["operational_g_per_inf"]
                    + d["embodied_g_per_inf"] <= hi), s
        # the total-carbon optimum can't be beaten by the CDP design
        assert (s["total_winner"]["total_g_per_inf"]
                <= s["cdp_winner"]["total_g_per_inf"] * (1 + 1e-6)), s
        assert {"ci_use_g_per_kwh", "lifetime_s", "util",
                "die_w"} <= set(s["op"]), s
    assert any(s["differs"] for s in tc), \
        "no scenario where the total-carbon winner differs from CDP"


def check_fleet(r: dict, expect_chaos: bool = False) -> None:
    assert r["bench"] == "fleet", r.get("bench")
    reps = r["replicas"]
    assert len(reps) >= 2, reps
    regions = {p["region"] for p in reps}
    assert len(regions) >= 2, regions   # different-intensity fleet
    for p in reps:
        assert {"name", "region", "alive", "routed", "completed",
                "carbon"} <= set(p), p
        assert p["carbon"]["energy_j"] >= 0, p
    # routing follows the grid: most requests went to the cleanest
    # live region at their routing instant
    assert r["routing"]["low_carbon_share"] >= 0.5, r["routing"]
    # failover: a replica was killed mid-trace, its in-flight work
    # re-queued, and NOTHING was lost
    fo = r["failover"]
    assert fo["killed"], fo
    assert fo["requeued"] >= 1, fo
    assert fo["lost"] == 0, fo
    assert r["totals"]["completed"] == r["totals"]["submitted"], r["totals"]
    # SLO held under carbon-aware placement
    slo = r["slo"]
    assert slo["ttft_p95_ticks"] <= slo["ttft_slo_ticks"], slo
    # metering on: per-token CO2e recorded and consistent
    t = r["totals"]
    assert t["energy_j"] > 0 and t["co2e_g"] > 0, t
    tol = 1e-9 + 1e-6 * t["co2e_g"]
    assert abs(t["co2e_g_per_token"] * t["tokens"] - t["co2e_g"]) <= tol, t
    if "retrace" in r:  # bench ran with --sanitize-retrace
        assert r["retrace"]["ok"] is True, r["retrace"]["findings"]
    if expect_chaos or "chaos" in r:   # bench ran with --chaos
        assert "chaos" in r, "fleet report has no 'chaos' section"
        c = r["chaos"]
        camp = c["campaign"]
        # the invariant gauntlet: zero lost, exactly-once, meter
        # conservation, deadline accounting, monotone tiers — all clean
        assert camp["ok"] is True, camp["violations"]
        assert camp["violations"] == [], camp["violations"]
        assert camp["lost"] == 0, camp
        # a real campaign: >=3 distinct fault kinds, at least one of
        # them a transient crash that the fleet recovered from
        kinds = camp["faults_by_kind"]
        assert len(kinds) >= 3, kinds
        assert (kinds.get("transient", 0) + kinds.get("submit_fault", 0)
                ) >= 1, kinds
        assert camp["recoveries"] >= 1, camp
        assert sum(camp["restarts"].values()) >= 1, camp["restarts"]
        # brownout A/B: the controller held the tight SLO by moving
        # tokens onto approx tiers, the uncontrolled fleet did not, and
        # exact service was restored once the burst drained
        b = c["brownout"]
        wc, wo = b["with_controller"], b["without_controller"]
        assert b["holds_slo"] is True, b
        assert wc["ttft_p95_ticks"] <= b["slo_ticks"], b
        assert b["improves_p95"] is True, b
        assert wc["degradation_events"] >= 2, wc   # degrade AND restore
        approx_tokens = sum(n for t, n in wc["tier_occupancy"].items()
                            if t != c["tiers"][0])
        assert approx_tokens > 0, wc["tier_occupancy"]
        assert b["restored_exact"] is True, wc["final_tiers"]
        # the uncontrolled fleet serves everything exact
        assert set(wo["tier_occupancy"]) <= {c["tiers"][0]}, wo


CHECKS = {"serving": check_serving, "gemm": check_gemm,
          "codesign": check_codesign, "fleet": check_fleet}


def check_report(r: dict, expect_mesh: dict | None = None,
                 expect_carbon: bool = False,
                 expect_chaos: bool = False,
                 expect_paged: bool = False,
                 expect_tuning: bool = False) -> str:
    """Dispatch on the report's "bench" field; returns the kind."""
    kind = r.get("bench")
    if kind not in CHECKS:
        raise AssertionError(
            f"unknown bench report kind {kind!r}; known: {list(CHECKS)}")
    if kind == "serving":
        check_serving(r, expect_mesh, expect_carbon, expect_paged)
    elif kind == "fleet":
        check_fleet(r, expect_chaos)
    elif kind == "gemm":
        check_gemm(r, expect_tuning)
    else:
        CHECKS[kind](r)
    return kind


def _parse_mesh(spec: str) -> dict:
    out = {}
    for part in spec.split(","):
        k, _, v = part.partition("=")
        out[k.strip()] = int(v)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("reports", nargs="+", help="BENCH_*.json files")
    ap.add_argument("--expect-mesh", default=None,
                    help="required engine mesh for serving reports, "
                         "e.g. data=4,model=2")
    ap.add_argument("--expect-carbon", action="store_true",
                    help="require serving reports to carry the --meter "
                         "energy/CO2e metrics")
    ap.add_argument("--expect-chaos", action="store_true",
                    help="require fleet reports to carry the --chaos "
                         "campaign + brownout section")
    ap.add_argument("--expect-paged", action="store_true",
                    help="require serving reports to carry the --trace "
                         "slot-vs-paged comparison (token identity, "
                         "allocator health, tick-TTFT gates) and the "
                         "speculative-decoding counters")
    ap.add_argument("--expect-tuning", action="store_true",
                    help="require gemm reports to be --autotune runs: "
                         "tuned tile blocks recorded per mode and the "
                         "chosen dispatch path within 1.05x of the "
                         "best-of-three measurement")
    args = ap.parse_args(argv)
    mesh = _parse_mesh(args.expect_mesh) if args.expect_mesh else None
    for path in args.reports:
        with open(path) as f:
            r = json.load(f)
        try:
            kind = check_report(r, mesh, args.expect_carbon,
                                args.expect_chaos, args.expect_paged,
                                args.expect_tuning)
        except AssertionError as e:
            print(f"[check_schema] {path}: FAIL\n{e}", file=sys.stderr)
            return 1
        print(f"[check_schema] {path}: {kind} OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
